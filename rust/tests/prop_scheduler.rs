//! Property tests for the scheduler layer: CBP (Function 1 / Table 1),
//! the DO algorithm (Function 2), De_Gl_Priority (α split) and the
//! block partitioner.

mod common;

use common::{prop_check, random_graph, random_partition, DEFAULT_CASES};
use tlsched::scheduler::{
    de_gl_priority, Cbp, DoSelector, JobQueue, PriorityPair,
};
use tlsched::util::rng::Pcg32;

fn random_pair(rng: &mut Pcg32, id: u32) -> PriorityPair {
    PriorityPair::new(id, rng.gen_range(100), rng.gen_f64() * 10.0)
}

#[test]
fn prop_cbp_antisymmetric_on_distinct_pairs() {
    prop_check("cbp antisymmetry", 2000, |rng| {
        let cbp = Cbp::new(rng.gen_f64() * 0.5);
        let a = random_pair(rng, 0);
        let b = random_pair(rng, 1);
        if (a.node_un, a.p_mean) == (b.node_un, b.p_mean) {
            return Ok(());
        }
        if a.is_converged() && b.is_converged() {
            return Ok(());
        }
        if cbp.higher(&a, &b) == cbp.higher(&b, &a) {
            return Err(format!("higher not antisymmetric for {a:?} / {b:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cbp_table1_always_cases() {
    prop_check("table1 cases 1/3/4", 2000, |rng| {
        let cbp = Cbp::default();
        let mean = 0.1 + rng.gen_f64() * 9.0;
        let lo_mean = mean * (0.1 + rng.gen_f64() * 0.8);
        let n_hi = 2 + rng.gen_range(50);
        let n_lo = 1 + rng.gen_range(n_hi - 1);
        // case 1: larger mean AND more unconverged
        let a = PriorityPair::new(0, n_hi, mean);
        let b = PriorityPair::new(1, n_lo, lo_mean);
        if !cbp.higher(&a, &b) {
            return Err(format!("case 1 violated: {a:?} vs {b:?}"));
        }
        // case 3: equal means, more nodes wins
        let c = PriorityPair::new(2, n_hi, mean);
        let d = PriorityPair::new(3, n_lo, mean);
        if !cbp.higher(&c, &d) {
            return Err(format!("case 3 violated: {c:?} vs {d:?}"));
        }
        // case 4: equal nodes, larger mean wins
        let e = PriorityPair::new(4, n_hi, mean);
        let f = PriorityPair::new(5, n_hi, lo_mean);
        if !cbp.higher(&e, &f) {
            return Err(format!("case 4 violated: {e:?} vs {f:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cbp_converged_always_loses() {
    prop_check("converged loses", 500, |rng| {
        let cbp = Cbp::default();
        let live = PriorityPair::new(0, 1 + rng.gen_range(50), 0.001 + rng.gen_f64());
        let dead = PriorityPair::new(1, 0, 0.0);
        if !cbp.higher(&live, &dead) || cbp.higher(&dead, &live) {
            return Err(format!("converged pair won against {live:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_do_select_output_ranked_and_unconverged() {
    prop_check("do output ranked", DEFAULT_CASES, |rng| {
        let b_n = 100 + rng.gen_index(5000);
        let table: Vec<PriorityPair> = (0..b_n)
            .map(|i| {
                let mut p = random_pair(rng, i as u32);
                if rng.gen_bool(0.3) {
                    p.node_un = 0; // converged
                    p.p_mean = 0.0;
                }
                p
            })
            .collect();
        let q = 1 + rng.gen_index(b_n / 2 + 1);
        let sel = DoSelector::default();
        let out = sel.select_top_q(&table, q, rng);
        if out.len() > 2 * q {
            return Err(format!("output {} exceeds 2q={}", out.len(), 2 * q));
        }
        if out.iter().any(|p| p.is_converged()) {
            return Err("converged block selected".into());
        }
        for w in out.windows(2) {
            if sel.cbp.higher(&w[1], &w[0]) {
                return Err(format!("not descending: {:?} before {:?}", w[0], w[1]));
            }
        }
        // distinct blocks
        let mut seen = std::collections::HashSet::new();
        for p in &out {
            if !seen.insert(p.block) {
                return Err(format!("duplicate block {}", p.block));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_do_select_recall_floor() {
    prop_check("do recall", 24, |rng| {
        let b_n = 2000 + rng.gen_index(20_000);
        let table: Vec<PriorityPair> =
            (0..b_n).map(|i| random_pair(rng, i as u32)).collect();
        let q = 50 + rng.gen_index(b_n / 20);
        let sel = DoSelector::default();
        let approx = sel.select_top_q(&table, q, rng);
        let exact = sel.exact_top_q(&table, q);
        let ids: std::collections::HashSet<u32> = approx.iter().map(|p| p.block).collect();
        let hits = exact.iter().filter(|p| ids.contains(&p.block)).count();
        let recall = hits as f64 / q as f64;
        if recall < 0.4 {
            return Err(format!("recall {recall:.3} below floor (B_N={b_n}, q={q})"));
        }
        Ok(())
    });
}

#[test]
fn prop_global_queue_invariants() {
    prop_check("global queue", DEFAULT_CASES, |rng| {
        let njobs = 1 + rng.gen_index(8);
        let qlen = 2 + rng.gen_index(20);
        let universe = 10 + rng.gen_index(200);
        let queues: Vec<JobQueue> = (0..njobs)
            .map(|j| {
                let mut blocks: Vec<u32> =
                    rng.sample_indices(universe, qlen).iter().map(|&b| b as u32).collect();
                rng.shuffle(&mut blocks);
                JobQueue {
                    job: j as u32,
                    queue: blocks
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| {
                            PriorityPair::new(b, (qlen - i) as u32, 1.0 + rng.gen_f64())
                        })
                        .collect(),
                }
            })
            .collect();
        let alpha = 0.1 + rng.gen_f64() * 0.9;
        let global = de_gl_priority(&queues, qlen, alpha);
        if global.len() > qlen {
            return Err(format!("global queue len {} > q {}", global.len(), qlen));
        }
        let mut seen = std::collections::HashSet::new();
        for e in &global {
            if !seen.insert(e.block) {
                return Err(format!("duplicate block {}", e.block));
            }
        }
        // every entry must come from some job queue
        for e in &global {
            if !queues.iter().any(|jq| jq.contains_block(e.block)) {
                return Err(format!("block {} not in any job queue", e.block));
            }
        }
        // main (non-reserved) prefix is score-sorted
        let main: Vec<_> = global.iter().filter(|e| !e.reserved).collect();
        for w in main.windows(2) {
            if w[0].score < w[1].score {
                return Err("main slots not score-descending".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_global_queue_reserved_covers_individual_tops() {
    prop_check("reserved slots", DEFAULT_CASES, |rng| {
        // construct: shared hot blocks + one unique top per job
        let njobs = 2 + rng.gen_index(5);
        let qlen = 6;
        let queues: Vec<JobQueue> = (0..njobs)
            .map(|j| {
                let mut blocks = vec![1000 + j as u32]; // unique top
                blocks.extend(0..(qlen as u32 - 1)); // shared tail
                JobQueue {
                    job: j as u32,
                    queue: blocks
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| PriorityPair::new(b, (qlen - i) as u32, 1.0))
                        .collect(),
                }
            })
            .collect();
        let global = de_gl_priority(&queues, qlen, 0.5);
        // with α=0.5, at least one unique individual top must be admitted
        let reserved_tops = global
            .iter()
            .filter(|e| e.block >= 1000)
            .count();
        if reserved_tops == 0 {
            return Err("no individual-top block admitted through reserved slots".into());
        }
        Ok(())
    });
}

#[test]
fn prop_partition_covers_exactly_once() {
    prop_check("partition coverage", DEFAULT_CASES, |rng| {
        let g = random_graph(rng);
        let part = random_partition(&g, rng);
        part.validate(&g).map_err(|e| e.to_string())?;
        let in_sum: u64 = part.blocks.iter().map(|b| b.in_edges).sum();
        if in_sum != g.num_edges() as u64 {
            return Err(format!("in-edge sum {} != m {}", in_sum, g.num_edges()));
        }
        Ok(())
    });
}

#[test]
fn prop_queue_length_bounds() {
    prop_check("eq4 bounds", 500, |rng| {
        let blocks = 1 + rng.gen_index(100_000);
        let vertices = blocks * (1 + rng.gen_index(1000));
        let c = rng.gen_f64() * 500.0;
        let q = tlsched::scheduler::optimal_queue_length(c, blocks, vertices);
        if q < 1 || q > blocks {
            return Err(format!("q={q} out of [1, {blocks}]"));
        }
        Ok(())
    });
}
