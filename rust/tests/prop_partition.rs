//! Property tests for the block partitioner and the shard splitter —
//! the edge cases that feed `BlockPartition::shard_by_bytes` (empty
//! graphs, one-vertex blocks, more shards than blocks) and the
//! `validate()` round-trips of both layers.

mod common;

use tlsched::graph::{generate, BlockPartition};

#[test]
fn prop_by_vertex_count_validates_on_random_graphs() {
    common::prop_check("by_vertex_count validates", 48, |rng| {
        let g = common::random_graph(rng);
        let part = common::random_partition(&g, rng);
        part.validate(&g).map_err(|e| format!("validate: {e}"))?;
        let in_sum: u64 = part.blocks.iter().map(|b| b.in_edges).sum();
        if in_sum != g.num_edges() as u64 {
            return Err(format!("in-edge sum {in_sum} != m {}", g.num_edges()));
        }
        Ok(())
    });
}

#[test]
fn prop_by_cache_budget_validates_across_budgets_and_jobs() {
    common::prop_check("by_cache_budget validates", 48, |rng| {
        let g = common::random_graph(rng);
        // budgets from absurdly small (clamps to the floor block size)
        // to huge (single block); job counts shrink blocks
        let budget = 1usize << (6 + rng.gen_index(26));
        let jobs = 1 + rng.gen_index(32);
        let part = BlockPartition::by_cache_budget(&g, budget, jobs);
        part.validate(&g).map_err(|e| format!("validate: {e}"))?;
        if part.num_blocks() == 0 {
            return Err("no blocks".into());
        }
        // a larger budget at the same job count never shrinks blocks
        let bigger = BlockPartition::by_cache_budget(&g, budget.saturating_mul(4), jobs);
        if bigger.target_vertices < part.target_vertices {
            return Err(format!(
                "budget x4 shrank blocks: {} -> {}",
                part.target_vertices, bigger.target_vertices
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_shard_by_bytes_round_trips_on_random_partitions() {
    common::prop_check("shard_by_bytes validates", 48, |rng| {
        let g = common::random_graph(rng);
        let part = common::random_partition(&g, rng);
        // shard counts crossing the block count in both directions
        let shards = 1 + rng.gen_index(2 * part.num_blocks() + 2);
        let ranges = part.shard_by_bytes(shards);
        if ranges.len() != shards {
            return Err(format!("{} ranges for {shards} shards", ranges.len()));
        }
        part.validate_shards(&ranges).map_err(|e| format!("validate_shards: {e}"))?;
        if part.num_blocks() >= shards && ranges.iter().any(|r| r.is_empty()) {
            return Err(format!(
                "empty shard with {} blocks over {shards} shards",
                part.num_blocks()
            ));
        }
        let covered: usize = ranges.iter().map(|r| r.num_vertices()).sum();
        if covered != g.num_vertices() {
            return Err(format!("shards cover {covered} of {} vertices", g.num_vertices()));
        }
        // balance: no shard exceeds its byte quantile by more than the
        // largest single block
        let total: u64 = ranges.iter().map(|r| r.bytes).sum();
        let max_block =
            part.blocks.iter().map(|b| b.structure_bytes()).max().unwrap_or(0);
        for r in &ranges {
            if r.bytes > total.div_ceil(shards as u64) + max_block {
                return Err(format!(
                    "shard {} holds {} of {total} bytes over {shards} shards",
                    r.id, r.bytes
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_one_vertex_blocks_shard_cleanly() {
    common::prop_check("one-vertex blocks", 24, |rng| {
        let g = common::random_graph(rng);
        if g.num_vertices() == 0 {
            return Ok(());
        }
        let part = BlockPartition::by_vertex_count(&g, 1);
        if part.num_blocks() != g.num_vertices() {
            return Err("one block per vertex expected".into());
        }
        part.validate(&g).map_err(|e| format!("validate: {e}"))?;
        for shards in [1usize, 2, part.num_blocks(), part.num_blocks() + 3] {
            let ranges = part.shard_by_bytes(shards);
            part.validate_shards(&ranges)
                .map_err(|e| format!("{shards} shards: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn empty_graph_partitions_and_shards() {
    let g = generate::erdos_renyi(0, 0, 7);
    assert_eq!(g.num_vertices(), 0);
    let part = BlockPartition::by_vertex_count(&g, 8);
    part.validate(&g).unwrap();
    assert_eq!(part.num_blocks(), 1, "sentinel empty block");
    let budgeted = BlockPartition::by_cache_budget(&g, 1 << 16, 4);
    budgeted.validate(&g).unwrap();
    for shards in [1usize, 2, 5] {
        let ranges = part.shard_by_bytes(shards);
        part.validate_shards(&ranges).unwrap();
        assert_eq!(ranges.iter().map(|r| r.num_vertices()).sum::<usize>(), 0);
    }
}
