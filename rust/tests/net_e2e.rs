//! End-to-end tests of the TCP serving front-end: loopback server,
//! concurrent clients, shared proto parser. Same convergence contract
//! as `serve_e2e.rs` — mid-flight submissions reach the batch
//! fixpoints (bit-identical for traversals, tolerance for the
//! PageRank family; bit-identical outright when pre-queued) — plus the
//! wire-level concerns: `REJECT busy` backpressure at queue
//! saturation, `REJECT parse` without killing the connection, and the
//! half-close shutdown drain that delivers every pending `DONE`.

use std::sync::{Arc, Barrier};
use std::time::Duration;
use tlsched::algorithms::DeltaProgram;
use tlsched::coordinator::{
    AdmissionConfig, AdmissionQueue, Coordinator, CoordinatorConfig, JobSubmitter,
};
use tlsched::engine::{JobSpec, JobState};
use tlsched::graph::{generate, BlockPartition, Graph};
use tlsched::net::{run_loadgen, Client, NetServer, NetServerConfig, Submitted};
use tlsched::scheduler::{SchedulerConfig, SchedulerKind};
use tlsched::trace::{JobKind, TraceJob};
use tlsched::util::json::Json;

fn setup(scale: u32) -> (Graph, BlockPartition) {
    let g = generate::rmat(scale, 8, 77);
    let part = BlockPartition::by_vertex_count(&g, 64);
    (g, part)
}

fn coord<'g>(
    g: &'g Graph,
    part: &'g BlockPartition,
    workers: usize,
    shards: usize,
) -> Coordinator<'g> {
    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.workers = workers;
    cfg.shards = shards;
    Coordinator::new(g, part, cfg)
}

fn start_server(g: &Graph, submitter: JobSubmitter) -> NetServer {
    let cfg = NetServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_connections: 16,
        ..Default::default()
    };
    NetServer::start(&cfg, submitter, g.num_vertices() as u32).unwrap()
}

fn sort_key(j: &JobState) -> (&'static str, u32) {
    (j.program.name(), j.spec.source)
}

/// Exact for traversals (unique schedule-independent fixpoint),
/// within program tolerance for the PageRank family.
fn assert_fixpoints_match(batch: &[JobState], serve: &[JobState]) {
    assert_eq!(batch.len(), serve.len());
    let mut b: Vec<&JobState> = batch.iter().collect();
    let mut s: Vec<&JobState> = serve.iter().collect();
    b.sort_by_key(|j| sort_key(j));
    s.sort_by_key(|j| sort_key(j));
    for (b, s) in b.iter().zip(&s) {
        assert_eq!(sort_key(b), sort_key(s), "jobs pair up by (kind, source)");
        assert!(s.converged);
        let exact = matches!(b.spec.kind, JobKind::Sssp | JobKind::Bfs | JobKind::Wcc);
        if exact {
            assert_eq!(b.values, s.values, "{}: exact fixpoint", b.program.name());
        } else {
            let tol = b.program.value_tolerance();
            for (x, y) in b.values.iter().zip(&s.values) {
                assert_eq!(x.is_finite(), y.is_finite());
                if x.is_finite() {
                    assert!((x - y).abs() < tol, "{}: {x} vs {y}", b.program.name());
                }
            }
        }
    }
}

/// Two concurrent clients trickle disjoint job sets over TCP while
/// earlier jobs are mid-iteration; everything must converge to the
/// batch fixpoints and every client gets exactly its own DONEs.
#[test]
fn tcp_mid_flight_submissions_converge_to_batch_fixpoints() {
    let (g, part) = setup(11);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Bfs, 3),
        JobSpec::new(JobKind::Wcc, 0),
        JobSpec::new(JobKind::Ppr, 17),
    ];
    let (bm, batch_jobs) = coord(&g, &part, 2, 1).run_batch_collect(&specs);
    assert_eq!(bm.completed(), 5);

    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let barrier = Arc::new(Barrier::new(2));
    let halves: Vec<Vec<JobSpec>> = vec![
        specs.iter().step_by(2).cloned().collect(),
        specs.iter().skip(1).step_by(2).cloned().collect(),
    ];
    let clients: Vec<_> = halves
        .into_iter()
        .map(|half| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
                barrier.wait(); // both connected before either submits
                let mut ids = Vec::new();
                for s in &half {
                    std::thread::sleep(Duration::from_millis(5)); // mid-flight joins
                    match c.submit(s.kind, s.source, None).unwrap() {
                        Submitted::Accepted(id) => ids.push(id),
                        Submitted::Rejected(r) => panic!("rejected: {r}"),
                    }
                }
                let mut done_ids: Vec<u64> =
                    ids.iter().map(|_| c.wait_done().unwrap().job_id).collect();
                let leftovers = c.quit().unwrap();
                assert!(leftovers.is_empty(), "all DONEs consumed before QUIT");
                done_ids.sort_unstable();
                ids.sort_unstable();
                assert_eq!(done_ids, ids, "a client sees exactly its own completions");
                ids.len()
            })
        })
        .collect();

    let mut srv = coord(&g, &part, 2, 1);
    let (sm, serve_jobs) =
        srv.serve_notify_collect(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    let submitted: usize = clients.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(submitted, 5);
    assert_eq!(sm.completed(), 5);
    assert!(sm.drained);
    let stats = server.finish();
    assert_eq!(stats.connections_total, 2);
    assert_eq!(stats.accepted, 5);
    assert_eq!(stats.done_sent, 5);
    assert_eq!((stats.rejected_parse, stats.rejected_busy, stats.done_dropped), (0, 0, 0));
    assert_fixpoints_match(&batch_jobs, &serve_jobs);
}

/// All jobs queued over TCP before the serve loop starts: serve
/// replays the exact batch round sequence, so fixpoints are
/// bit-identical — including the PageRank family, and on the sharded
/// runtime too.
#[test]
fn tcp_prequeued_matches_batch_bitwise_sharded_and_unsharded() {
    let (g, part) = setup(9);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Wcc, 0),
        JobSpec::new(JobKind::Bfs, 3),
        JobSpec::new(JobKind::Ppr, 17),
    ];
    for shards in [1usize, 2] {
        let (bm, batch_jobs) = coord(&g, &part, 2, shards).run_batch_collect(&specs);
        assert_eq!(bm.completed(), 5);

        let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
        let server = start_server(&g, submitter);
        let addr = server.local_addr().to_string();
        let client_specs = specs.clone();
        let client = std::thread::spawn(move || {
            let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
            let mut ids = Vec::new();
            for s in &client_specs {
                match c.submit(s.kind, s.source, None).unwrap() {
                    Submitted::Accepted(id) => ids.push(id),
                    Submitted::Rejected(r) => panic!("rejected: {r}"),
                }
            }
            for _ in &ids {
                c.wait_done().unwrap();
            }
            c.quit().unwrap();
        });
        // hold the serve loop until every submission is queued, so the
        // round sequence replays the batch exactly
        while server.stats().accepted < 5 {
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut srv = coord(&g, &part, 2, shards);
        let (sm, serve_jobs) =
            srv.serve_notify_collect(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
        client.join().unwrap();
        server.finish();
        assert_eq!(sm.completed(), 5, "shards={shards}");
        assert!(sm.drained);
        assert_eq!(batch_jobs.len(), serve_jobs.len());
        for (b, s) in batch_jobs.iter().zip(&serve_jobs) {
            assert_eq!(b.spec.kind, s.spec.kind, "admission preserved submission order");
            assert_eq!(b.updates, s.updates, "{}: work counters", b.program.name());
            assert_eq!(b.rounds, s.rounds, "{}: round counts", b.program.name());
            assert_eq!(b.values, s.values, "{}: bit-identical", b.program.name());
            assert_eq!(b.deltas, s.deltas, "{}: deltas bit-identical", b.program.name());
        }
    }
}

/// Saturating `--queue-capacity` surfaces as wire-level `REJECT busy`
/// — deterministically, without ever blocking the accept loop (a
/// second client can still connect and query STATUS mid-saturation).
#[test]
fn tcp_backpressure_surfaces_reject_busy_on_the_wire() {
    let (g, part) = setup(8);
    let acfg = AdmissionConfig { queue_capacity: 2, ..Default::default() };
    let (submitter, mut queue) = AdmissionQueue::live(&acfg, 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let (saturated_tx, saturated_rx) = std::sync::mpsc::channel();
    let client_addr = addr.clone();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&client_addr, Duration::from_secs(5)).unwrap();
        // nothing drains yet (the serve loop starts later): exactly
        // capacity submissions are ACKed, the rest REJECT busy
        let outcomes: Vec<Submitted> =
            (0..6u32).map(|i| c.submit(JobKind::Bfs, i * 7, None).unwrap()).collect();
        saturated_tx.send(()).unwrap();
        let acked = outcomes.iter().filter(|o| matches!(o, Submitted::Accepted(_))).count();
        for _ in 0..acked {
            c.wait_done().unwrap();
        }
        c.quit().unwrap();
        outcomes
    });
    saturated_rx.recv().unwrap();
    // accept loop alive under saturation: a second connection answers
    let mut probe = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    let status = Json::parse(&probe.status().unwrap()).unwrap();
    assert_eq!(status.get("rejected_busy").unwrap().as_u64(), Some(4));
    assert_eq!(status.get("in_flight").unwrap().as_u64(), Some(2));
    probe.quit().unwrap();

    let mut srv = coord(&g, &part, 1, 1);
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    let outcomes = client.join().unwrap();
    let rejected: Vec<String> = outcomes
        .iter()
        .filter_map(|o| match o {
            Submitted::Rejected(r) => Some(r.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(rejected, vec!["busy"; 4], "queue saturation is a wire-level REJECT busy");
    assert_eq!(m.completed(), 2);
    assert_eq!(m.rejected, 4, "coordinator metrics agree with the wire");
    assert!(m.drained);
    let stats = server.finish();
    assert_eq!(stats.accepted, 2);
    assert_eq!(stats.rejected_busy, 4);
    assert_eq!(stats.done_sent, 2);
}

/// Malformed lines get `REJECT parse <detail>` and the connection
/// survives to submit valid work afterwards.
#[test]
fn tcp_malformed_lines_reject_parse_without_killing_connection() {
    let (g, part) = setup(8);
    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let bad = ["frobnicate 3", "bfs notanumber", "pagerank 0 soon", "bfs 1 2.0 x", "SUBMIT"];
        let mut reasons = Vec::new();
        for b in bad {
            match c.submit_line(b).unwrap() {
                Submitted::Rejected(r) => reasons.push(r),
                Submitted::Accepted(id) => panic!("'{b}' accepted as {id}"),
            }
        }
        // the same socket still takes valid work
        match c.submit_line("bfs 3").unwrap() {
            Submitted::Accepted(_) => {}
            Submitted::Rejected(r) => panic!("valid line rejected: {r}"),
        }
        c.wait_done().unwrap();
        c.quit().unwrap();
        reasons
    });
    let mut srv = coord(&g, &part, 1, 1);
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    let reasons = client.join().unwrap();
    assert_eq!(reasons.len(), 5);
    assert!(reasons.iter().all(|r| r.starts_with("parse ")), "{reasons:?}");
    assert_eq!(m.completed(), 1);
    let stats = server.finish();
    assert_eq!(stats.rejected_parse, 5);
    assert_eq!(stats.accepted, 1);
    assert_eq!(stats.connections_total, 1, "rejects never killed the connection");
}

/// `QUIT` right after submitting: the server half-closes — it stops
/// reading but delivers every pending `DONE` before EOF, so no
/// completion notification is ever dropped on a graceful shutdown.
#[test]
fn tcp_quit_drains_pending_done_notifications() {
    let (g, part) = setup(9);
    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let mut ids = Vec::new();
        for (kind, src) in [(JobKind::PageRank, 0), (JobKind::Bfs, 3), (JobKind::Wcc, 0)] {
            match c.submit(kind, src, None).unwrap() {
                Submitted::Accepted(id) => ids.push(id),
                Submitted::Rejected(r) => panic!("rejected: {r}"),
            }
        }
        // quit immediately, completions still pending
        let dones = c.quit().unwrap();
        (ids, dones)
    });
    let mut srv = coord(&g, &part, 2, 1);
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    let (mut ids, dones) = client.join().unwrap();
    let mut done_ids: Vec<u64> = dones.iter().map(|d| d.job_id).collect();
    ids.sort_unstable();
    done_ids.sort_unstable();
    assert_eq!(done_ids, ids, "every ACKed job's DONE arrived before close");
    for d in &dones {
        assert!(d.rounds > 0);
        assert!(d.queue_wait_s >= 0.0);
        assert!(d.exec_s >= 0.0);
    }
    assert_eq!(m.completed(), 3);
    assert!(m.drained, "final snapshot carries the drained flag");
    let stats = server.finish();
    assert_eq!(stats.done_sent, 3);
    assert_eq!(stats.done_dropped, 0);
}

/// The closed loop the CI smoke runs in-process: loadgen replays a
/// trace over three connections and every job comes back with a
/// latency sample.
#[test]
fn loadgen_closed_loop_over_loopback() {
    let (g, part) = setup(8);
    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let jobs: Vec<TraceJob> = (0..12)
        .map(|i| TraceJob {
            id: i,
            arrival_s: i as f64 * 20.0,
            service_s: 1.0,
            kind: JobKind::ALL[i as usize % 5],
            source: (i * 31) as u32,
        })
        .collect();
    let lg = std::thread::spawn(move || {
        run_loadgen(&addr, &jobs, 3, 1.0e4, Duration::from_secs(5)).unwrap()
    });
    let mut srv = coord(&g, &part, 2, 1);
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    let report = lg.join().unwrap();
    assert_eq!(report.connections, 3);
    assert_eq!(report.sent, 12);
    assert_eq!(report.acked, 12);
    assert_eq!(report.done, 12);
    assert_eq!(report.rejected_parse, 0);
    assert_eq!(report.latencies_s.len(), 12, "every completion has a latency sample");
    assert!(report.p_latency_s(50.0) > 0.0);
    assert!(report.p_latency_s(95.0) >= report.p_latency_s(50.0));
    assert!(report.completed_per_s() > 0.0);
    assert!(Json::parse(&report.to_json().to_string()).is_ok());
    assert_eq!(m.completed(), 12);
    assert!(m.drained);
    server.finish();
}
