//! Integration tests of the paged graph snapshot (`.pbin`,
//! DESIGN.md §11): the multi-process sharing story — one writer, many
//! concurrent mmap readers over the same file — plus the config-layer
//! wiring (`graph = file` with a `.pbin` path) and copy-on-write
//! isolation between readers.

use tlsched::config::{GraphSource, RunConfig};
use tlsched::graph::{generate, Graph, GraphSnapshot};

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("tlsched_paged_{}_{name}", std::process::id()));
    p
}

fn assert_same_graph(a: &Graph, b: &Graph) {
    assert_eq!(a.num_vertices(), b.num_vertices());
    assert_eq!(a.num_edges(), b.num_edges());
    assert_eq!(a.out_offsets, b.out_offsets);
    assert_eq!(a.out_targets, b.out_targets);
    assert_eq!(a.in_offsets, b.in_offsets);
    assert_eq!(a.in_sources, b.in_sources);
    assert_eq!(a.out_weights, b.out_weights);
    assert_eq!(a.in_weights, b.in_weights);
}

/// N threads open the same snapshot concurrently — the shard-group
/// cold-start path, where every `serve` process maps one read-only
/// file — and each sees the full CSR, validated and equal to the
/// in-memory original.
#[test]
fn concurrent_readers_share_one_snapshot() {
    let g = generate::rmat(9, 8, 31);
    let path = tmp("concurrent.pbin");
    GraphSnapshot::write(&g, &path).unwrap();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let g = &g;
            let path = &path;
            s.spawn(move || {
                let snap = GraphSnapshot::open_mapped(path).unwrap();
                assert_same_graph(snap.graph(), g);
                // validate() already ran at open; spot-check the
                // traversal surface the engine actually uses
                for v in (0..g.num_vertices() as u32).step_by(17) {
                    assert_eq!(snap.graph().out_neighbors(v), g.out_neighbors(v));
                }
            });
        }
    });
    std::fs::remove_file(&path).unwrap();
}

/// Two mapped readers over one file: a write through one (promoting
/// its lane to owned via copy-on-write) is invisible to the other and
/// to later opens of the file.
#[test]
fn copy_on_write_isolates_mapped_readers() {
    let g = generate::road_grid(7, 9, 2);
    assert!(g.is_weighted());
    let path = tmp("cow.pbin");
    GraphSnapshot::write(&g, &path).unwrap();
    let mut a = GraphSnapshot::open_mapped(&path).unwrap().into_graph();
    let b = GraphSnapshot::open_mapped(&path).unwrap().into_graph();
    let orig = a.out_targets[0];
    a.out_targets[0] = orig.wrapping_add(1);
    assert_eq!(a.out_targets[0], orig.wrapping_add(1));
    assert_eq!(b.out_targets[0], orig, "readers are isolated");
    let fresh = GraphSnapshot::open_mapped(&path).unwrap();
    assert_eq!(fresh.graph().out_targets[0], orig, "the file is untouched");
    assert_same_graph(fresh.graph(), &g);
    std::fs::remove_file(&path).unwrap();
}

/// `graph = file` with a `.pbin` path goes through the mapped-open
/// path — the exact route `serve --source tcp` processes take when
/// sharing one snapshot.
#[test]
fn run_config_builds_graph_from_pbin() {
    let g = generate::rmat(8, 8, 5);
    let path = tmp("config.pbin");
    GraphSnapshot::write(&g, &path).unwrap();
    let mut cfg = RunConfig::default();
    cfg.graph = GraphSource::File(path.to_string_lossy().into_owned());
    let loaded = cfg.build_graph().unwrap();
    assert_same_graph(&loaded, &g);
    std::fs::remove_file(&path).unwrap();
}
