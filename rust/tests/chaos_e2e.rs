//! Chaos end-to-end suite (DESIGN.md §9): drive the full TCP serving
//! stack — listener, admission queue, coordinator serve loop — under
//! deterministic injected faults and prove the containment contract:
//!
//! * an injected panic in one job's block task fails exactly that job
//!   (`FAIL` on the wire), every other resident job converges to its
//!   batch fixpoint bit-identically (traversals have schedule-
//!   independent unique fixpoints), and the server stays up;
//! * an abruptly dropped client (no half-close) costs only its own
//!   pending notifications (`done_dropped`), never the server or the
//!   jobs themselves;
//! * deadline breaches surface as `FAIL deadline` terminal lines;
//! * queue saturation surfaces as `REJECT busy`, and the bounded-
//!   backoff retry path eventually lands the job once capacity frees;
//! * in every scenario each `ACK`ed job gets **exactly one** terminal
//!   response: `acked == done_sent + fail_sent + done_dropped`.
//!
//! The injector is process-global, so every test serializes on one
//! mutex and disarms via a drop guard. CI runs this binary under
//! several `TLSCHED_FAULTS=seed=N` values; the structural plan of each
//! test is fixed, only the seed (jitter, delay pattern) varies.

use std::sync::Mutex;
use std::time::Duration;
use tlsched::coordinator::{
    AdmissionConfig, AdmissionQueue, Coordinator, CoordinatorConfig, JobSubmitter,
};
use tlsched::engine::{JobSpec, JobState};
use tlsched::graph::{generate, BlockPartition, Graph};
use tlsched::net::{Client, NetServer, NetServerConfig, RetryPolicy, Submitted};
use tlsched::scheduler::{SchedulerConfig, SchedulerKind};
use tlsched::trace::JobKind;
use tlsched::util::faults::{self, FaultPlan};

/// The fault plan and its fired/ack state are process-global; chaos
/// tests must never overlap. Poisoning is survivable (a failed test
/// must not cascade), hence the into_inner fallback.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarm + clear the injector on every exit path, panicking included.
struct FaultGuard;

impl Drop for FaultGuard {
    fn drop(&mut self) {
        faults::disarm();
        faults::install(FaultPlan::default());
    }
}

/// Seed for this run's plans: CI exports `TLSCHED_FAULTS=seed=N` to
/// sweep seeds; the structural faults below stay fixed so every seed
/// tests the same scenario with different jitter/delay patterns.
fn env_seed() -> u64 {
    std::env::var("TLSCHED_FAULTS")
        .ok()
        .and_then(|s| FaultPlan::parse(&s).ok())
        .map_or(7, |p| p.seed)
}

fn setup(scale: u32) -> (Graph, BlockPartition) {
    let g = generate::rmat(scale, 8, 77);
    let part = BlockPartition::by_vertex_count(&g, 64);
    (g, part)
}

fn coord<'g>(
    g: &'g Graph,
    part: &'g BlockPartition,
    workers: usize,
    shards: usize,
) -> Coordinator<'g> {
    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.workers = workers;
    cfg.shards = shards;
    Coordinator::new(g, part, cfg)
}

fn start_server(g: &Graph, submitter: JobSubmitter) -> NetServer {
    let cfg = NetServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_connections: 16,
        ..Default::default()
    };
    NetServer::start(&cfg, submitter, g.num_vertices() as u32).unwrap()
}

/// Injected panic in job 0's block task: the victim gets `FAIL
/// injected_panic_*` on the wire, the three traversal jobs submitted
/// beside it converge to their batch fixpoints **bit-identically**, and
/// the wire contract `acked == done_sent + fail_sent + done_dropped`
/// holds — on the unsharded and the sharded round engine.
#[test]
fn injected_panic_quarantines_victim_survivors_reach_batch_fixpoints() {
    let _l = lock();
    let _g = FaultGuard;
    let (g, part) = setup(9);
    let survivors =
        vec![JobSpec::new(JobKind::Sssp, 10), JobSpec::new(JobKind::Bfs, 3), JobSpec::new(JobKind::Wcc, 0)];

    for shards in [1usize, 2] {
        // fault-free reference fixpoints for the survivors (traversals:
        // unique schedule-independent fixpoints, so the co-resident
        // victim cannot perturb them)
        let (bm, batch_jobs) = coord(&g, &part, 2, shards).run_batch_collect(&survivors);
        assert_eq!(bm.completed(), 3);

        // fresh plan per engine (install resets the fire-once latch):
        // panic in job 0 once it has run 3 rounds, plus torn writes and
        // a sprinkle of deterministic block delays for schedule chaos
        faults::install(FaultPlan {
            seed: env_seed(),
            panic_job: Some((0, 3)),
            delay: Some((1, 0.05)),
            short_write: true,
            ..Default::default()
        });
        faults::arm();

        let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
        let server = start_server(&g, submitter);
        let addr = server.local_addr().to_string();
        let client_survivors = survivors.clone();
        let client = std::thread::spawn(move || {
            let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
            // victim first, so FIFO admission hands it coordinator job
            // id 0 — the id the fault plan names
            let mut ids = Vec::new();
            for (kind, source) in std::iter::once((JobKind::PageRank, 0))
                .chain(client_survivors.iter().map(|s| (s.kind, s.source)))
            {
                match c.submit(kind, source, None).unwrap() {
                    Submitted::Accepted(id) => ids.push(id),
                    Submitted::Rejected(r) => panic!("rejected: {r}"),
                }
            }
            let mut fails = Vec::new();
            let mut dones = Vec::new();
            for _ in &ids {
                let comp = c.wait_done().unwrap();
                if comp.is_failed() {
                    fails.push(comp);
                } else {
                    dones.push(comp.job_id);
                }
            }
            let leftovers = c.quit().unwrap();
            assert!(leftovers.is_empty(), "each ACK got exactly one terminal line");
            (ids, fails, dones)
        });
        // hold the serve loop until everything is queued: FIFO pop order
        // then fixes the job-id assignment (victim = 0)
        while server.stats().accepted < 4 {
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut srv = coord(&g, &part, 2, shards);
        let (sm, serve_jobs) =
            srv.serve_notify_collect(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
        let (ids, fails, dones) = client.join().unwrap();
        assert_eq!(ids.len(), 4, "shards={shards}");
        assert_eq!(fails.len(), 1, "exactly the victim failed (shards={shards})");
        assert_eq!(dones.len(), 3);
        let reason = fails[0].fail_reason.as_deref().unwrap();
        assert!(reason.starts_with("injected_panic"), "shards={shards}: {reason}");

        // the serve loop survived to a clean drain, with the failure in
        // its own metrics bucket
        assert!(sm.drained, "shards={shards}");
        assert_eq!(sm.completed(), 3, "shards={shards}");
        assert_eq!(sm.failed(), 1, "shards={shards}");
        let stats = server.finish();
        assert_eq!(stats.accepted, 4);
        assert_eq!((stats.done_sent, stats.fail_sent, stats.done_dropped), (3, 1, 0));
        assert_eq!(
            stats.accepted,
            stats.done_sent + stats.fail_sent + stats.done_dropped,
            "every ACK resolves to exactly one terminal response"
        );

        // survivors reached the batch fixpoints bit-identically — the
        // quarantined round touched no other job's lane
        let converged: Vec<&JobState> =
            serve_jobs.iter().filter(|j| j.converged).collect();
        assert_eq!(converged.len(), 3, "shards={shards}");
        for b in &batch_jobs {
            let s = converged
                .iter()
                .find(|s| s.program.name() == b.program.name())
                .unwrap_or_else(|| panic!("{} missing from serve run", b.program.name()));
            assert_eq!(b.values, s.values, "{}: bit-identical fixpoint", b.program.name());
        }
        faults::disarm();
    }
}

/// Injected abrupt connection drop right after the first ACK: the
/// dead client's pending notification lands in `done_dropped` (the
/// wire contract stays balanced), the job itself still runs to
/// completion, and a sibling connection is completely unaffected.
#[test]
fn abrupt_client_drop_costs_only_its_own_notifications() {
    let _l = lock();
    let _g = FaultGuard;
    let (g, part) = setup(9);
    faults::install(FaultPlan {
        seed: env_seed(),
        drop_conn_after_acks: Some(1),
        ..Default::default()
    });
    faults::arm();

    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let client = std::thread::spawn(move || {
        // both connections exist before the drop, so the victim's exit
        // cannot trigger the last-client-out shutdown
        let mut doomed = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let mut healthy = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        match doomed.submit(JobKind::PageRank, 0, None).unwrap() {
            Submitted::Accepted(_) => {}
            Submitted::Rejected(r) => panic!("rejected: {r}"),
        }
        // the server tears the socket down without a drain: the next
        // read sees EOF, never a DONE
        let err = doomed.wait_done();
        assert!(err.is_err(), "dropped connection must not receive terminals: {err:?}");
        match healthy.submit(JobKind::Bfs, 3, None).unwrap() {
            Submitted::Accepted(_) => {}
            Submitted::Rejected(r) => panic!("rejected: {r}"),
        }
        let comp = healthy.wait_done().unwrap();
        assert!(!comp.is_failed(), "sibling connection unaffected");
        healthy.quit().unwrap();
    });
    let mut srv = coord(&g, &part, 2, 1);
    let sm = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    client.join().unwrap();
    // both jobs ran to completion — a vanished client is a network
    // fault, not a job fault
    assert_eq!(sm.completed(), 2);
    assert_eq!(sm.failed(), 0);
    assert!(sm.drained);
    let stats = server.finish();
    assert_eq!(stats.accepted, 2);
    assert_eq!(stats.done_sent, 1);
    assert_eq!(stats.done_dropped, 1, "the dead peer's DONE is accounted, not lost");
    assert_eq!(
        stats.accepted,
        stats.done_sent + stats.fail_sent + stats.done_dropped,
        "wire contract balanced under an abrupt drop"
    );
}

/// Deadline enforcement end to end: a job submitted with an already-
/// hopeless deadline under `deadline_grace = 1.0` is cancelled at a
/// round boundary and terminates on the wire as `FAIL deadline`; a
/// deadline-less sibling completes untouched.
#[test]
fn deadline_breach_terminates_as_wire_fail() {
    let _l = lock(); // no faults armed; lock only excludes armed siblings
    let (g, part) = setup(9);
    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let doomed = match c.submit(JobKind::PageRank, 0, Some(1e-9)).unwrap() {
            Submitted::Accepted(id) => id,
            Submitted::Rejected(r) => panic!("rejected: {r}"),
        };
        match c.submit(JobKind::Bfs, 3, None).unwrap() {
            Submitted::Accepted(_) => {}
            Submitted::Rejected(r) => panic!("rejected: {r}"),
        }
        let mut fail = None;
        let mut done = None;
        for _ in 0..2 {
            let comp = c.wait_done().unwrap();
            if comp.is_failed() {
                fail = Some(comp);
            } else {
                done = Some(comp);
            }
        }
        c.quit().unwrap();
        let fail = fail.expect("the overdue job must FAIL");
        assert_eq!(fail.job_id, doomed);
        assert_eq!(fail.fail_reason.as_deref(), Some("deadline"));
        assert!(done.is_some(), "the deadline-less sibling completed");
    });
    let mut srv = coord(&g, &part, 2, 1);
    srv.cfg.deadline_grace = 1.0;
    let sm = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    client.join().unwrap();
    assert_eq!(sm.completed(), 1);
    assert_eq!(sm.cancelled(), 1);
    assert!(sm.drained);
    let stats = server.finish();
    assert_eq!((stats.done_sent, stats.fail_sent), (1, 1));
    assert_eq!(stats.accepted, stats.done_sent + stats.fail_sent + stats.done_dropped);
}

/// Queue saturation + client retry: with a capacity-1 queue and no
/// consumer, the second submission is a deterministic `REJECT busy`;
/// once the serve loop starts draining, the bounded-backoff retry path
/// lands the same line, and both jobs complete — so a saturated period
/// still ends with every submission resolved as DONE or REJECT.
#[test]
fn saturated_queue_rejects_busy_then_retry_lands_when_capacity_frees() {
    let _l = lock();
    let (g, part) = setup(8);
    let acfg = AdmissionConfig { queue_capacity: 1, ..Default::default() };
    let (submitter, mut queue) = AdmissionQueue::live(&acfg, 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let (saturated_tx, saturated_rx) = std::sync::mpsc::channel();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        match c.submit_line("bfs 1").unwrap() {
            Submitted::Accepted(_) => {}
            Submitted::Rejected(r) => panic!("rejected: {r}"),
        }
        // nothing consumes yet: saturation is deterministic
        match c.submit_line("bfs 2").unwrap() {
            Submitted::Rejected(r) => assert_eq!(r, "busy"),
            Submitted::Accepted(id) => panic!("queue over capacity accepted {id}"),
        }
        saturated_tx.send(()).unwrap();
        // serve loop is starting: bounded backoff until capacity frees
        let policy = RetryPolicy { retries: 20, backoff_ms: 2, seed: env_seed() };
        let (out, _tries) = c.submit_line_retry("bfs 2", policy).unwrap();
        assert!(
            matches!(out, Submitted::Accepted(_)),
            "retry landed once the queue drained: {out:?}"
        );
        for _ in 0..2 {
            let comp = c.wait_done().unwrap();
            assert!(!comp.is_failed());
        }
        c.quit().unwrap();
    });
    saturated_rx.recv().unwrap();
    let mut srv = coord(&g, &part, 1, 1);
    let sm = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
    client.join().unwrap();
    assert_eq!(sm.completed(), 2);
    assert!(sm.drained);
    let stats = server.finish();
    assert_eq!(stats.accepted, 2);
    assert!(stats.rejected_busy >= 1, "saturation surfaced on the wire");
    assert_eq!(stats.done_sent, 2);
    assert_eq!(stats.accepted, stats.done_sent + stats.fail_sent + stats.done_dropped);
}
