//! Parity suite for the sharded execution runtime, extending the
//! determinism contract of `tests/fused_parity.rs` across scheduler
//! shards:
//!
//! 1. **S = 1 anchor** — a 1-shard round is bit-identical to the
//!    unsharded staged engine (`Scheduler::round_parallel`) for both
//!    block-major policies.
//! 2. **Traversal bit-parity across shard counts** — for min-combine
//!    programs (SSSP/BFS/WCC) every round's lanes and counters are
//!    bit-identical at S ∈ {1, 2, 4} × workers ∈ {1, 4}: the
//!    dispatched (block, job) set is a pure function of the exact
//!    integer summaries, and min-folds are order-insensitive bit for
//!    bit.
//! 3. **Fixpoint equivalence for the PageRank family** — f32
//!    accumulation order differs across shard counts, so runs to
//!    convergence agree within program tolerance (exactly for
//!    traversals).
//! 4. **Worker independence** — at a fixed shard count, rounds are
//!    bit-identical for any worker count.
//! 5. **Serving** — a sharded coordinator admitting jobs mid-flight
//!    converges to the sharded batch fixpoints.
//!
//! The CI shard-parity leg runs this suite at `SHARDS={1,2,4}`; set
//! the `SHARDS` env var to pin the non-reference shard count (the
//! S = 1 reference always runs).

use tlsched::algorithms::DeltaProgram;
use tlsched::coordinator::{
    AdmissionConfig, AdmissionQueue, Coordinator, CoordinatorConfig, JobRequest,
};
use tlsched::engine::{JobSpec, JobState};
use tlsched::graph::{generate, BlockPartition, Graph};
use tlsched::scheduler::{RoundStats, Scheduler, SchedulerConfig, SchedulerKind};
use tlsched::shard::{run_to_convergence_sharded, ShardedRuntime};
use tlsched::trace::JobKind;
use tlsched::util::threadpool::ThreadPool;

/// Shard counts under test: with `SHARDS` set (the CI matrix), `[1]`
/// for the cheap S = 1 anchor leg or `[1, $SHARDS]` for a sharded
/// leg; `[1, 2, 4]` when unset (local `cargo test`).
fn shard_counts() -> Vec<usize> {
    match std::env::var("SHARDS").ok().and_then(|s| s.parse::<usize>().ok()) {
        Some(s) if s > 1 => vec![1, s],
        Some(_) => vec![1],
        None => vec![1, 2, 4],
    }
}

const BLOCK_MAJOR: [SchedulerKind; 2] =
    [SchedulerKind::RoundRobinBlocks, SchedulerKind::TwoLevel];

fn mixed_jobs(g: &Graph, n: usize) -> Vec<JobState> {
    (0..n)
        .map(|i| {
            JobState::new(
                i as u32,
                JobSpec::new(
                    JobKind::ALL[i % 5],
                    (i as u32 * 131) % g.num_vertices() as u32,
                ),
                g,
            )
        })
        .collect()
}

/// Traversal-only mix: min-combine programs with exact,
/// schedule-independent f32 fixpoints.
fn traversal_jobs(g: &Graph, n: usize) -> Vec<JobState> {
    let kinds = [JobKind::Sssp, JobKind::Bfs, JobKind::Wcc];
    (0..n)
        .map(|i| {
            JobState::new(
                i as u32,
                JobSpec::new(
                    kinds[i % 3],
                    (i as u32 * 97) % g.num_vertices() as u32,
                ),
                g,
            )
        })
        .collect()
}

fn assert_lanes_eq(a: &[JobState], b: &[JobState], ctx: &str) {
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.values, y.values, "values diverge: {ctx} (job {})", x.id);
        assert_eq!(x.deltas, y.deltas, "deltas diverge: {ctx} (job {})", x.id);
        assert_eq!(x.updates, y.updates, "updates diverge: {ctx} (job {})", x.id);
        assert_eq!(x.edges, y.edges, "edges diverge: {ctx} (job {})", x.id);
    }
}

fn assert_values_close(a: &[JobState], b: &[JobState], tol_mult: f32, ctx: &str) {
    for (x, y) in a.iter().zip(b) {
        let exact = matches!(x.spec.kind, JobKind::Sssp | JobKind::Bfs | JobKind::Wcc);
        if exact {
            assert_eq!(x.values, y.values, "{ctx}: job {} exact fixpoint", x.id);
            continue;
        }
        let tol = x.program.value_tolerance() * tol_mult;
        for (vi, (p, q)) in x.values.iter().zip(&y.values).enumerate() {
            assert_eq!(p.is_finite(), q.is_finite(), "{ctx}: job {} v{vi}", x.id);
            if p.is_finite() {
                assert!((p - q).abs() < tol, "{ctx}: job {} v{vi}: {p} vs {q}", x.id);
            }
        }
    }
}

// ---- 1. S = 1 anchors the unsharded engine ----------------------------

#[test]
fn single_shard_rounds_match_unsharded_engine_bitwise() {
    let g = generate::rmat(10, 8, 83);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for kind in BLOCK_MAJOR {
        for workers in [1usize, 4] {
            let pool = ThreadPool::new(workers);
            let mut jobs_ref = mixed_jobs(&g, 6);
            let mut jobs_sh = mixed_jobs(&g, 6);
            let mut sched = Scheduler::new(SchedulerConfig::new(kind));
            let mut rt = ShardedRuntime::new(&part, SchedulerConfig::new(kind), 1);
            for round in 0..5 {
                let a = sched.round_parallel(&g, &part, &mut jobs_ref, &pool);
                let b = rt.round(&g, &part, &mut jobs_sh, &pool);
                assert_eq!(a, b, "{} w={workers} round {round} stats", kind.name());
                assert_lanes_eq(
                    &jobs_ref,
                    &jobs_sh,
                    &format!("{} w={workers} round {round}", kind.name()),
                );
            }
        }
    }
}

// ---- 2. traversal rounds bit-identical across shard counts ------------

#[test]
fn traversal_rounds_bit_identical_across_shard_counts() {
    let g = generate::rmat(10, 8, 89);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for kind in BLOCK_MAJOR {
        let mut reference: Option<(Vec<JobState>, Vec<RoundStats>)> = None;
        for shards in shard_counts() {
            for workers in [1usize, 4] {
                let pool = ThreadPool::new(workers);
                let mut jobs = traversal_jobs(&g, 6);
                let mut rt =
                    ShardedRuntime::new(&part, SchedulerConfig::new(kind), shards);
                let stats: Vec<RoundStats> =
                    (0..6).map(|_| rt.round(&g, &part, &mut jobs, &pool)).collect();
                match &reference {
                    None => reference = Some((jobs, stats)),
                    Some((rj, rs)) => {
                        assert_eq!(
                            rs,
                            &stats,
                            "{} S={shards} w={workers} stats",
                            kind.name()
                        );
                        assert_lanes_eq(
                            rj,
                            &jobs,
                            &format!("{} S={shards} w={workers}", kind.name()),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn traversal_convergence_bit_identical_across_shard_counts() {
    let g = generate::rmat(10, 8, 97);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let pool = ThreadPool::new(4);
    for kind in BLOCK_MAJOR {
        let mut reference: Option<(Vec<JobState>, usize)> = None;
        for shards in shard_counts() {
            let mut jobs = traversal_jobs(&g, 6);
            let mut rt = ShardedRuntime::new(&part, SchedulerConfig::new(kind), shards);
            let (rounds, stats) =
                run_to_convergence_sharded(&mut rt, &g, &part, &mut jobs, &pool, 1_000_000);
            assert!(stats.updates > 0, "{} S={shards}", kind.name());
            assert!(jobs.iter().all(|j| j.converged), "{} S={shards}", kind.name());
            match &reference {
                None => reference = Some((jobs, rounds)),
                Some((rj, rr)) => {
                    assert_eq!(*rr, rounds, "{} S={shards} rounds", kind.name());
                    assert_lanes_eq(rj, &jobs, &format!("{} S={shards}", kind.name()));
                }
            }
        }
    }
}

// ---- 3. PageRank family: fixpoint equivalence -------------------------

#[test]
fn mixed_fixpoints_equivalent_across_shard_counts() {
    let g = generate::rmat(10, 8, 101);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for kind in BLOCK_MAJOR {
        let mut reference: Option<Vec<JobState>> = None;
        for shards in shard_counts() {
            for workers in [1usize, 4] {
                let pool = ThreadPool::new(workers);
                let mut jobs = mixed_jobs(&g, 5);
                let mut rt =
                    ShardedRuntime::new(&part, SchedulerConfig::new(kind), shards);
                run_to_convergence_sharded(&mut rt, &g, &part, &mut jobs, &pool, 1_000_000);
                assert!(
                    jobs.iter().all(|j| j.converged),
                    "{} S={shards} w={workers}",
                    kind.name()
                );
                match &reference {
                    None => reference = Some(jobs),
                    Some(r) => assert_values_close(
                        r,
                        &jobs,
                        4.0,
                        &format!("{} S={shards} w={workers}", kind.name()),
                    ),
                }
            }
        }
    }
}

// ---- 4. fixed shard count, any worker count ---------------------------

#[test]
fn sharded_rounds_bit_identical_across_worker_counts() {
    let g = generate::rmat(10, 8, 103);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for shards in shard_counts() {
        let mut reference: Option<Vec<JobState>> = None;
        for workers in [1usize, 2, 4] {
            let pool = ThreadPool::new(workers);
            let mut jobs = mixed_jobs(&g, 6);
            let mut rt = ShardedRuntime::new(
                &part,
                SchedulerConfig::new(SchedulerKind::TwoLevel),
                shards,
            );
            for _ in 0..6 {
                rt.round(&g, &part, &mut jobs, &pool);
            }
            match &reference {
                None => reference = Some(jobs),
                Some(r) => {
                    assert_lanes_eq(r, &jobs, &format!("S={shards} w={workers}"))
                }
            }
        }
    }
}

// ---- 5. serving: mid-flight admission on the sharded coordinator ------

fn sharded_coord<'g>(
    g: &'g Graph,
    part: &'g BlockPartition,
    shards: usize,
) -> Coordinator<'g> {
    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.workers = 2;
    cfg.shards = shards;
    Coordinator::new(g, part, cfg)
}

#[test]
fn serve_sharded_mid_flight_converges_to_batch_fixpoints() {
    let g = generate::rmat(10, 8, 107);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Bfs, 3),
        JobSpec::new(JobKind::Wcc, 0),
    ];
    for shards in shard_counts() {
        let (bm, batch_jobs) = sharded_coord(&g, &part, shards).run_batch_collect(&specs);
        assert_eq!(bm.completed(), 4);

        let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1.0);
        let feeder_specs = specs.clone();
        let feeder = std::thread::spawn(move || {
            submitter.submit(JobRequest::new(feeder_specs[0].kind, feeder_specs[0].source)).unwrap();
            for s in &feeder_specs[1..] {
                std::thread::sleep(std::time::Duration::from_millis(5));
                submitter.submit(JobRequest::new(s.kind, s.source)).unwrap();
            }
        });
        let mut server = sharded_coord(&g, &part, shards);
        let (sm, serve_jobs) = server.serve_collect(&mut queue, 0.0, |_| {});
        feeder.join().unwrap();
        assert_eq!(sm.completed(), 4, "S={shards}");
        if shards > 1 {
            assert_eq!(sm.shards.len(), shards, "serve metrics carry shard counters");
            assert_eq!(
                sm.shards.iter().map(|s| s.updates).sum::<u64>(),
                sm.totals.updates,
                "S={shards}"
            );
        }
        assert_eq!(batch_jobs.len(), serve_jobs.len());
        for (b, s) in batch_jobs.iter().zip(&serve_jobs) {
            assert_eq!(b.spec.kind, s.spec.kind, "S={shards}: admission order");
            assert!(s.converged);
        }
        assert_values_close(&batch_jobs, &serve_jobs, 1.0, &format!("serve S={shards}"));
    }
}

#[test]
fn sharded_batch_matches_unsharded_fixpoints_via_coordinator() {
    let g = generate::rmat(10, 8, 109);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Ppr, 17),
        JobSpec::new(JobKind::Wcc, 0),
    ];
    let (_, unsharded) = sharded_coord(&g, &part, 1).run_batch_collect(&specs);
    for shards in shard_counts().into_iter().filter(|&s| s > 1) {
        let (m, sharded) = sharded_coord(&g, &part, shards).run_batch_collect(&specs);
        assert_eq!(m.completed(), specs.len());
        assert_eq!(m.shards.len(), shards);
        assert_values_close(&unsharded, &sharded, 4.0, &format!("batch S={shards}"));
    }
}
