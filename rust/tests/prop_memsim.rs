//! Property tests for the memsim substrate (DESIGN.md §4) and the
//! locality observatory built on it (DESIGN.md §13): set-associative
//! LRU invariants, address-map region disjointness, hierarchy stats
//! conservation, and determinism of sampled profiling.

mod common;

use common::{prop_check, random_graph, random_partition};
use tlsched::memsim::{
    AddressMap, Cache, CacheConfig, HierarchyConfig, HierarchyStats, MemoryHierarchy, Region,
};
use tlsched::obs::locality::LocalitySampler;
use tlsched::util::rng::Pcg32;

fn random_cache_config(rng: &mut Pcg32) -> CacheConfig {
    let line_size = 32usize << rng.gen_range(3); // 32|64|128|256
    let assoc = 1usize << (1 + rng.gen_range(3)); // 2|4|8
    let sets = 1usize << (2 + rng.gen_range(5)); // 4..64
    CacheConfig {
        capacity: line_size * assoc * sets,
        line_size,
        assoc,
        hit_latency: 1 + rng.next_u64() % 8,
    }
}

/// Within one set: `assoc` distinct lines are all simultaneously
/// resident (every re-access hits), and inserting one more line evicts
/// exactly the LRU way — the evicted line misses on return while the
/// most-recently-used line still hits.
#[test]
fn prop_lru_set_invariants() {
    prop_check("lru_set_invariants", 64, |rng| {
        let cfg = random_cache_config(rng);
        let mut c = Cache::new(cfg);
        let sets = cfg.sets() as u64;
        let set = rng.next_u64() % sets;
        let line = |i: u64| (set + i * sets) * cfg.line_size as u64;
        for i in 0..cfg.assoc as u64 {
            if c.access(line(i)) {
                return Err(format!("cold access of line {i} hit"));
            }
        }
        for i in 0..cfg.assoc as u64 {
            if !c.access(line(i)) {
                return Err(format!(
                    "line {i} of {} resident lines missed (assoc {})",
                    cfg.assoc, cfg.assoc
                ));
            }
        }
        // LRU order is now 0..assoc again; one more line evicts way 0
        let extra = cfg.assoc as u64;
        if c.access(line(extra)) {
            return Err("conflicting line hit a full set".into());
        }
        if !c.access(line(extra - 1)) {
            return Err("MRU survivor was evicted instead of the LRU way".into());
        }
        if c.access(line(0)) {
            return Err("LRU line survived an eviction that must have removed it".into());
        }
        Ok(())
    });
}

/// Every region of the simulated layout — the six shared-structure
/// arrays and each job's value/delta lanes — occupies a disjoint byte
/// range, for any graph shape and job count. Overlap would let one
/// job's lane writes masquerade as graph-structure reuse.
#[test]
fn prop_address_map_regions_disjoint() {
    prop_check("address_map_regions_disjoint", 48, |rng| {
        let g = random_graph(rng);
        let map = AddressMap::new(&g);
        let n = g.num_vertices() as u64;
        let m = g.num_edges() as u64;
        let jobs = 2 + rng.gen_range(5);
        let mut spans: Vec<(&'static str, u64, u64)> = vec![
            (
                "in_offsets",
                map.addr(Region::InOffsets, 0),
                map.addr(Region::InOffsets, n) + 8,
            ),
            (
                "out_offsets",
                map.addr(Region::OutOffsets, 0),
                map.addr(Region::OutOffsets, n) + 8,
            ),
        ];
        if m > 0 {
            for (name, r) in [
                ("in_sources", Region::InSources),
                ("in_weights", Region::InWeights),
                ("out_targets", Region::OutTargets),
                ("out_weights", Region::OutWeights),
            ] {
                spans.push((name, map.addr(r, 0), map.addr(r, m - 1) + 4));
            }
        }
        for j in 0..jobs {
            for (name, r) in [("values", Region::Values(j)), ("deltas", Region::Deltas(j))] {
                spans.push((name, map.addr(r, 0), map.addr(r, n - 1) + 4));
            }
        }
        for (i, a) in spans.iter().enumerate() {
            for b in spans.iter().skip(i + 1) {
                if a.2 > b.1 && b.2 > a.1 {
                    return Err(format!(
                        "{} [{}, {}) overlaps {} [{}, {}) at {jobs} jobs",
                        a.0, a.1, a.2, b.0, b.1, b.2
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Conservation across the inclusive hierarchy for an arbitrary access
/// stream: per level hits + misses == accesses, each inner level's
/// misses are exactly the next level's accesses, and DRAM sees exactly
/// the LLC misses.
#[test]
fn prop_hierarchy_stats_conservation() {
    prop_check("hierarchy_stats_conservation", 48, |rng| {
        let cfg = match rng.gen_range(3) {
            0 => HierarchyConfig::tiny(),
            1 => HierarchyConfig::small(),
            _ => HierarchyConfig::default(),
        };
        let mut mem = MemoryHierarchy::new(cfg);
        let footprint = 1u64 << (14 + rng.gen_range(8)); // 16K..2M bytes
        let accesses = 2_000 + rng.gen_index(8_000);
        let mut cursor = rng.next_u64() % footprint;
        for _ in 0..accesses {
            // mixed stream: mostly short sequential runs, some jumps
            if rng.gen_range(8) == 0 {
                cursor = rng.next_u64() % footprint;
            } else {
                cursor = (cursor + 4) % footprint;
            }
            mem.access(cursor);
        }
        let s = mem.stats();
        for (lvl, cs) in [("l1", s.l1), ("l2", s.l2), ("llc", s.llc)] {
            if cs.hits + cs.misses != cs.accesses {
                return Err(format!(
                    "{lvl}: hits {} + misses {} != accesses {}",
                    cs.hits, cs.misses, cs.accesses
                ));
            }
        }
        if s.l1.accesses != accesses as u64 {
            return Err(format!("l1 saw {} of {} issued accesses", s.l1.accesses, accesses));
        }
        if s.l2.accesses != s.l1.misses {
            return Err(format!("l2 accesses {} != l1 misses {}", s.l2.accesses, s.l1.misses));
        }
        if s.llc.accesses != s.l2.misses {
            return Err(format!("llc accesses {} != l2 misses {}", s.llc.accesses, s.l2.misses));
        }
        if s.dram_accesses != s.llc.misses {
            return Err(format!("dram {} != llc misses {}", s.dram_accesses, s.llc.misses));
        }
        Ok(())
    });
}

fn stats_fields(s: &HierarchyStats) -> [u64; 12] {
    [
        s.l1.accesses,
        s.l1.hits,
        s.l1.misses,
        s.l2.accesses,
        s.l2.hits,
        s.l2.misses,
        s.llc.accesses,
        s.llc.hits,
        s.llc.misses,
        s.dram_accesses,
        s.stall_cycles,
        s.work_cycles,
    ]
}

/// Two samplers fed the identical round/block stream produce identical
/// heat vectors, round summaries, and simulated hierarchy stats — the
/// observatory's replay is a pure function of its input stream, never
/// of wall clock or task interleaving (`flush_current` sorts).
#[test]
fn prop_sampled_profiling_deterministic() {
    prop_check("sampled_profiling_deterministic", 24, |rng| {
        let g = random_graph(rng);
        let part = random_partition(&g, rng);
        let sample = 1 + rng.next_u64() % 4;
        let jobs: Vec<u32> = (0..(1 + rng.gen_range(4))).collect();
        let fused = rng.gen_range(2) == 0;
        let hcfg = HierarchyConfig::tiny();
        let mut a = LocalitySampler::new(hcfg, sample, &g, &part);
        let mut b = LocalitySampler::new(hcfg, sample, &g, &part);
        let rounds = 3 + rng.gen_index(6);
        let nb = part.blocks.len();
        for _ in 0..rounds {
            let sa = a.begin_round();
            let sb = b.begin_round();
            match (&sa, &sb) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    if x.touched != y.touched || x.mean_sharing != y.mean_sharing {
                        return Err(format!("round summaries diverged: {x:?} vs {y:?}"));
                    }
                }
                _ => return Err("one sampler flushed, the other did not".into()),
            }
            let touches = 1 + rng.gen_index(nb.min(8));
            for _ in 0..touches {
                let blk = rng.gen_index(nb) as u32;
                a.record_block(&g, blk, &jobs, fused);
                b.record_block(&g, blk, &jobs, fused);
            }
        }
        if a.heat() != b.heat() {
            return Err("heat vectors diverged".into());
        }
        if stats_fields(&a.stats()) != stats_fields(&b.stats()) {
            return Err(format!(
                "hierarchy stats diverged: {:?} vs {:?}",
                stats_fields(&a.stats()),
                stats_fields(&b.stats())
            ));
        }
        if a.sampled_rounds() != b.sampled_rounds() || a.rounds_seen() != b.rounds_seen() {
            return Err("round clocks diverged".into());
        }
        Ok(())
    });
}
