//! Mini property-testing framework (proptest substitute — see DESIGN.md
//! §3). Runs a seeded closure over many generated cases; on failure it
//! reports the case index and seed so the exact input can be replayed
//! with `TLSCHED_PROP_SEED=<seed> TLSCHED_PROP_CASE=<i>`.

use tlsched::util::rng::Pcg32;

#[allow(dead_code)]
pub const DEFAULT_CASES: usize = 64;

/// Run `body` over `cases` generated inputs. `body` receives a fresh,
/// deterministic RNG per case and returns `Err(description)` to fail.
pub fn prop_check<F>(name: &str, cases: usize, mut body: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let seed: u64 = std::env::var("TLSCHED_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfeed_2018);
    let only_case: Option<usize> =
        std::env::var("TLSCHED_PROP_CASE").ok().and_then(|s| s.parse().ok());
    for case in 0..cases {
        if let Some(c) = only_case {
            if case != c {
                continue;
            }
        }
        let mut rng = Pcg32::new(seed, case as u64);
        if let Err(msg) = body(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay with \
                 TLSCHED_PROP_SEED={seed} TLSCHED_PROP_CASE={case}): {msg}"
            );
        }
    }
}

/// Random graph for property tests: mixes generator families and sizes.
#[allow(dead_code)]
pub fn random_graph(rng: &mut Pcg32) -> tlsched::graph::Graph {
    use tlsched::graph::generate;
    let seed = rng.next_u64();
    match rng.gen_range(4) {
        0 => {
            let n = 16 + rng.gen_index(400);
            let m = n * (1 + rng.gen_index(8));
            generate::erdos_renyi(n, m, seed)
        }
        1 => {
            let scale = 5 + rng.gen_range(4);
            generate::rmat(scale, 4 + rng.gen_index(8), seed)
        }
        2 => {
            let n = 20 + rng.gen_index(300);
            generate::barabasi_albert(n, 2 + rng.gen_index(3), seed)
        }
        _ => {
            let r = 3 + rng.gen_index(12);
            let c = 3 + rng.gen_index(12);
            generate::road_grid(r, c, seed)
        }
    }
}

/// Random block partition of a graph.
#[allow(dead_code)]
pub fn random_partition(
    g: &tlsched::graph::Graph,
    rng: &mut Pcg32,
) -> tlsched::graph::BlockPartition {
    let n = g.num_vertices().max(1);
    let vb = 1 + rng.gen_index(n);
    tlsched::graph::BlockPartition::by_vertex_count(g, vb)
}
