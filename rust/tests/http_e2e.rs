//! End-to-end tests of the HTTP/JSON gateway: loopback server, the
//! same convergence contract as `net_e2e.rs` — jobs submitted over
//! HTTP while earlier jobs are mid-iteration reach the batch fixpoints
//! (bit-identical for traversals, tolerance for the PageRank family) —
//! plus the gateway-specific concerns: structured `429 busy` rejects at
//! queue saturation, the exactly-once terminal-state retention
//! contract (`GET /jobs/<id>` delivers a retired job's outcome exactly
//! once, then 404), and malformed bodies/request lines never killing
//! the listener.

use std::time::Duration;
use tlsched::coordinator::{
    AdmissionConfig, AdmissionQueue, Coordinator, CoordinatorConfig, JobSubmitter,
};
use tlsched::engine::{JobSpec, JobState};
use tlsched::graph::{generate, BlockPartition, Graph};
use tlsched::net::{run_http_loadgen, HttpClient, HttpServer, HttpServerConfig};
use tlsched::scheduler::{SchedulerConfig, SchedulerKind};
use tlsched::trace::{JobKind, TraceJob};
use tlsched::util::json::Json;

fn setup(scale: u32) -> (Graph, BlockPartition) {
    let g = generate::rmat(scale, 8, 77);
    let part = BlockPartition::by_vertex_count(&g, 64);
    (g, part)
}

fn coord<'g>(g: &'g Graph, part: &'g BlockPartition, workers: usize) -> Coordinator<'g> {
    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.workers = workers;
    Coordinator::new(g, part, cfg)
}

fn start_server(g: &Graph, submitter: JobSubmitter) -> HttpServer {
    let cfg = HttpServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_connections: 16,
        ..Default::default()
    };
    HttpServer::start(&cfg, submitter, g.num_vertices() as u32).unwrap()
}

/// Poll `id` until its terminal state arrives (the serve loop is
/// running concurrently), with a generous guard against hangs.
fn poll_terminal(c: &mut HttpClient, id: u64) -> Json {
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let (st, body) = c.poll(id).unwrap();
        assert_eq!(st, 200, "job {id} must be pending or terminal while polling: {body}");
        if body.get_str("state") != Some("pending") {
            return body;
        }
        assert!(std::time::Instant::now() < deadline, "job {id} never retired");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn sort_key(j: &JobState) -> (&'static str, u32) {
    (j.program.name(), j.spec.source)
}

/// Exact for traversals (unique schedule-independent fixpoint), within
/// program tolerance for the PageRank family — the identical predicate
/// `net_e2e.rs` holds the TCP front to.
fn assert_fixpoints_match(batch: &[JobState], serve: &[JobState]) {
    assert_eq!(batch.len(), serve.len());
    let mut b: Vec<&JobState> = batch.iter().collect();
    let mut s: Vec<&JobState> = serve.iter().collect();
    b.sort_by_key(|j| sort_key(j));
    s.sort_by_key(|j| sort_key(j));
    for (b, s) in b.iter().zip(&s) {
        assert_eq!(sort_key(b), sort_key(s), "jobs pair up by (kind, source)");
        assert!(s.converged);
        let exact = matches!(b.spec.kind, JobKind::Sssp | JobKind::Bfs | JobKind::Wcc);
        if exact {
            assert_eq!(b.values, s.values, "{}: exact fixpoint", b.program.name());
        } else {
            let tol = b.program.value_tolerance();
            for (x, y) in b.values.iter().zip(&s.values) {
                assert_eq!(x.is_finite(), y.is_finite());
                if x.is_finite() {
                    assert!((x - y).abs() < tol, "{}: {x} vs {y}", b.program.name());
                }
            }
        }
    }
}

/// Jobs trickled in over HTTP while earlier jobs are mid-iteration
/// converge to the batch fixpoints, each terminal state is delivered
/// exactly once (second poll: 404), and `POST /shutdown` retires the
/// gateway so the serve loop drains cleanly.
#[test]
fn http_mid_flight_submissions_converge_to_batch_fixpoints() {
    let (g, part) = setup(11);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Bfs, 3),
        JobSpec::new(JobKind::Wcc, 0),
        JobSpec::new(JobKind::Ppr, 17),
    ];
    let (bm, batch_jobs) = coord(&g, &part, 2).run_batch_collect(&specs);
    assert_eq!(bm.completed(), 5);

    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let client_specs = specs.clone();
    let client = std::thread::spawn(move || {
        let mut c = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let mut ids = Vec::new();
        for s in &client_specs {
            std::thread::sleep(Duration::from_millis(5)); // mid-flight joins
            let (st, body) = c.submit(s.kind, s.source, None).unwrap();
            assert_eq!(st, 200, "{body}");
            assert_eq!(body.get_str("state"), Some("accepted"));
            ids.push(body.get_u64("id").unwrap());
        }
        let mut done = 0;
        for &id in &ids {
            let body = poll_terminal(&mut c, id);
            assert_eq!(body.get_u64("id"), Some(id));
            assert_eq!(body.get_str("state"), Some("done"), "{body}");
            assert!(body.get_u64("rounds").unwrap() > 0);
            assert!(body.get_f64("queue_wait_s").unwrap() >= 0.0);
            assert!(body.get_f64("exec_s").unwrap() >= 0.0);
            done += 1;
            // retention contract: the terminal state was handed out
            // exactly once — a second poll finds nothing
            let (st, _) = c.poll(id).unwrap();
            assert_eq!(st, 404, "job {id} delivered exactly once");
        }
        let (st, _) = c.shutdown().unwrap();
        assert_eq!(st, 200);
        done
    });

    let mut srv = coord(&g, &part, 2);
    let (sm, serve_jobs) = srv.serve_notify_collect(&mut queue, 0.0, |_| {}, |rec| {
        server.notify_done(rec);
    });
    let done = client.join().unwrap();
    assert_eq!(done, 5);
    assert_eq!(sm.completed(), 5);
    assert!(sm.drained);
    let stats = server.finish();
    assert_eq!(stats.accepted, 5);
    assert_eq!(stats.delivered, 5);
    assert_eq!((stats.rejected_busy, stats.rejected_parse, stats.terminals_evicted), (0, 0, 0));
    assert_fixpoints_match(&batch_jobs, &serve_jobs);
}

/// Saturating the bounded queue surfaces as structured `429 busy`
/// rejects, the ops surface answers mid-saturation from a second
/// connection, and the accepted jobs still converge and deliver their
/// terminal states once the serve loop runs.
#[test]
fn http_backpressure_surfaces_structured_429() {
    let (g, part) = setup(8);
    let acfg = AdmissionConfig { queue_capacity: 2, ..Default::default() };
    let (submitter, mut queue) = AdmissionQueue::live(&acfg, 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();

    // nothing drains yet (the serve loop starts later): exactly
    // capacity submissions are accepted, the rest 429
    let mut c = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    let mut ids = Vec::new();
    let mut busy = 0;
    for i in 0..6u32 {
        let (st, body) = c.submit(JobKind::Bfs, i * 7, None).unwrap();
        match st {
            200 => ids.push(body.get_u64("id").unwrap()),
            429 => {
                assert_eq!(body.get_str("error"), Some("busy"), "structured reject");
                busy += 1;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert_eq!((ids.len(), busy), (2, 4));

    // ops surface answers mid-saturation from a fresh connection
    let mut probe = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    let (st, status) = probe.request("GET", "/status", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(status.get_u64("rejected_busy"), Some(4));
    assert_eq!(status.get_u64("accepted"), Some(2));
    assert_eq!(status.get_u64("pending"), Some(2));
    drop(probe);

    let mut srv = coord(&g, &part, 1);
    let client = std::thread::spawn(move || {
        let terminals: Vec<Json> =
            ids.iter().map(|&id| poll_terminal(&mut c, id)).collect();
        let _ = c.shutdown();
        terminals
    });
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| {
        server.notify_done(rec);
    });
    let terminals = client.join().unwrap();
    assert_eq!(terminals.len(), 2);
    for t in &terminals {
        assert_eq!(t.get_str("state"), Some("done"), "{t}");
    }
    assert_eq!(m.completed(), 2);
    assert_eq!(m.rejected, 4, "coordinator metrics agree with the gateway");
    assert!(m.drained);
    let stats = server.finish();
    assert_eq!(stats.accepted, 2);
    assert_eq!(stats.rejected_busy, 4);
    assert_eq!(stats.delivered, 2);
}

/// Malformed bodies get a structured 400 and the connection — and
/// listener — survive; torn request lines close their connection with
/// 400 but never take the accept loop down. Valid work still flows
/// afterwards on the same socket and on fresh ones.
#[test]
fn http_malformed_input_never_kills_the_listener() {
    let (g, part) = setup(8);
    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();

    let client = std::thread::spawn(move || {
        let mut c = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let bad_bodies = [
            "",                                        // empty
            "not json",                                // not JSON at all
            "[1,2]",                                   // wrong shape
            "{\"kind\":\"frobnicate\"}",               // unknown kind
            "{\"kind\":\"bfs\",\"source\":-1}",        // bad source
            "{\"kind\":\"bfs\",\"deadline_s\":\"x\"}", // bad deadline
        ];
        for b in bad_bodies {
            let (st, body) = c.request("POST", "/jobs", Some(b)).unwrap();
            assert_eq!(st, 400, "{b:?} must be rejected: {body}");
            assert!(body.get_str("error").is_some(), "reject carries a reason: {body}");
        }
        // the same connection still takes valid work
        let (st, body) = c.submit(JobKind::Bfs, 3, None).unwrap();
        assert_eq!(st, 200, "connection survived six parse rejects: {body}");
        let id = body.get_u64("id").unwrap();
        let done = poll_terminal(&mut c, id);
        assert_eq!(done.get_str("state"), Some("done"));

        // torn request lines 400 and close — on fresh connections, so
        // the keep-alive one above is untouched
        use std::io::{BufRead, BufReader, Write};
        for garbage in ["NOT HTTP AT ALL\r\n\r\n", "GET\r\n\r\n"] {
            let mut s = std::net::TcpStream::connect(&addr).unwrap();
            s.write_all(garbage.as_bytes()).unwrap();
            let mut line = String::new();
            let _ = BufReader::new(&mut s).read_line(&mut line);
            assert!(line.contains("400"), "{garbage:?} -> {line:?}");
        }

        // the listener is still accepting and serving after all of it
        let mut c2 = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        let (st, body) = c2.submit(JobKind::Wcc, 0, None).unwrap();
        assert_eq!(st, 200, "{body}");
        let id2 = body.get_u64("id").unwrap();
        assert_eq!(poll_terminal(&mut c2, id2).get_str("state"), Some("done"));
        let (st, status) = c2.request("GET", "/status", None).unwrap();
        assert_eq!(st, 200);
        let parse_rejects = status.get_u64("rejected_parse").unwrap();
        let bad_requests = status.get_u64("bad_requests").unwrap();
        let _ = c2.shutdown();
        (parse_rejects, bad_requests)
    });

    let mut srv = coord(&g, &part, 1);
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| {
        server.notify_done(rec);
    });
    let (parse_rejects, bad_requests) = client.join().unwrap();
    assert_eq!(parse_rejects, 6, "every malformed body counted, none fatal");
    assert_eq!(bad_requests, 2, "torn request lines counted, listener alive");
    assert_eq!(m.completed(), 2);
    assert!(m.drained);
    let stats = server.finish();
    assert_eq!(stats.delivered, 2);
}

/// The closed loop the CI smoke runs in-process: the HTTP loadgen
/// replays a trace, polls every job to its terminal state with a
/// latency sample, and shuts the gateway down itself.
#[test]
fn http_loadgen_closed_loop_over_loopback() {
    let (g, part) = setup(8);
    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    let server = start_server(&g, submitter);
    let addr = server.local_addr().to_string();
    let jobs: Vec<TraceJob> = (0..12)
        .map(|i| TraceJob {
            id: i,
            arrival_s: i as f64 * 20.0,
            service_s: 1.0,
            kind: JobKind::ALL[i as usize % 5],
            source: (i * 31) as u32,
        })
        .collect();
    let lg = std::thread::spawn(move || {
        run_http_loadgen(&addr, &jobs, 3, 1.0e4, Duration::from_secs(5)).unwrap()
    });
    let mut srv = coord(&g, &part, 2);
    let m = srv.serve_notify(&mut queue, 0.0, |_| {}, |rec| {
        server.notify_done(rec);
    });
    let report = lg.join().unwrap();
    assert_eq!(report.connections, 3);
    assert_eq!(report.sent, 12);
    assert_eq!(report.acked, 12);
    assert_eq!(report.done, 12);
    assert_eq!(report.rejected_parse, 0);
    assert_eq!(report.latencies_s.len(), 12, "every completion has a latency sample");
    assert!(report.p_latency_s(50.0) > 0.0);
    assert!(report.p_latency_s(95.0) >= report.p_latency_s(50.0));
    assert!(report.completed_per_s() > 0.0);
    assert!(Json::parse(&report.to_json().to_string()).is_ok());
    assert_eq!(m.completed(), 12);
    assert!(m.drained);
    server.finish();
}
