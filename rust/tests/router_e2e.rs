//! End-to-end tests of the source-affine router (DESIGN.md §11), run
//! fully in-process: a [`Router`] fronting real shard-group stacks —
//! each its own admission queue, TCP server and coordinator serve
//! loop over the same graph. The contract under test:
//!
//! * **fixpoint parity** — jobs routed across groups converge to the
//!   single-process batch fixpoints (exact for traversals, within
//!   program tolerance for the PageRank family);
//! * **source affinity** — each job lands on exactly the group that
//!   owns its source vertex's block, per the byte-balanced table;
//! * **exactly one terminal** — every ACKed job produces one
//!   `DONE`/`FAIL`, including when a group dies mid-stream
//!   (`FAIL <tag> group_down`), never zero and never two.

use std::time::Duration;
use tlsched::coordinator::{AdmissionConfig, AdmissionQueue, Coordinator, CoordinatorConfig};
use tlsched::engine::{JobSpec, JobState};
use tlsched::graph::{generate, BlockPartition, Graph};
use tlsched::net::{proto, Client, NetServer, NetServerConfig, Router, RouterConfig, Submitted};
use tlsched::scheduler::{SchedulerConfig, SchedulerKind};
use tlsched::trace::JobKind;

fn setup(scale: u32) -> (Graph, BlockPartition) {
    let g = generate::rmat(scale, 8, 77);
    let part = BlockPartition::by_vertex_count(&g, 64);
    (g, part)
}

fn coord<'g>(g: &'g Graph, part: &'g BlockPartition, workers: usize) -> Coordinator<'g> {
    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.workers = workers;
    Coordinator::new(g, part, cfg)
}

fn start_group(g: &Graph) -> (AdmissionQueue, NetServer) {
    let (submitter, queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1.0);
    let cfg = NetServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_connections: 16,
        ..Default::default()
    };
    let server = NetServer::start(&cfg, submitter, g.num_vertices() as u32).unwrap();
    (queue, server)
}

fn router_over(groups: Vec<String>, part: BlockPartition, nv: u32) -> Router {
    let rcfg = RouterConfig {
        net: NetServerConfig {
            listen: "127.0.0.1:0".to_string(),
            max_connections: 16,
            ..Default::default()
        },
        time_scale: 1.0,
        groups,
        ..Default::default()
    };
    Router::start(&rcfg, part, nv).unwrap()
}

fn sort_key(j: &JobState) -> (&'static str, u32) {
    (j.program.name(), j.spec.source)
}

/// Exact for traversals (unique schedule-independent fixpoint),
/// within program tolerance for the PageRank family.
fn assert_fixpoints_match(batch: &[JobState], routed: &[JobState]) {
    assert_eq!(batch.len(), routed.len());
    let mut b: Vec<&JobState> = batch.iter().collect();
    let mut r: Vec<&JobState> = routed.iter().collect();
    b.sort_by_key(|j| sort_key(j));
    r.sort_by_key(|j| sort_key(j));
    for (b, r) in b.iter().zip(&r) {
        assert_eq!(sort_key(b), sort_key(r), "jobs pair up by (kind, source)");
        assert!(r.converged);
        let exact = matches!(b.spec.kind, JobKind::Sssp | JobKind::Bfs | JobKind::Wcc);
        if exact {
            assert_eq!(b.values, r.values, "{}: exact fixpoint", b.program.name());
        } else {
            let tol = b.program.value_tolerance();
            for (x, y) in b.values.iter().zip(&r.values) {
                assert_eq!(x.is_finite(), y.is_finite());
                if x.is_finite() {
                    assert!((x - y).abs() < tol, "{}: {x} vs {y}", b.program.name());
                }
            }
        }
    }
}

/// Jobs spanning two shard groups, submitted through the router,
/// converge to the single-process batch fixpoints; every job gets
/// exactly one `DONE`, and each lands on the group the table assigns.
#[test]
fn router_fixpoint_parity_across_two_groups() {
    let (g, part) = setup(10);
    let nv = g.num_vertices() as u32;
    // pick sources on both sides of the two-way shard split
    let shards = part.shard_by_bytes(2);
    let s0 = shards[0].vertices.start;
    let s1 = shards[1].vertices.start;
    let specs = vec![
        JobSpec::new(JobKind::PageRank, s0),
        JobSpec::new(JobKind::Sssp, s1),
        JobSpec::new(JobKind::Bfs, s0 + 1),
        JobSpec::new(JobKind::Wcc, s1),
        JobSpec::new(JobKind::Ppr, s1 + 1),
    ];
    // the affinity table the router will derive — expected per-group load
    let mut block_group = vec![0u32; part.num_blocks()];
    for s in &shards {
        for b in s.blocks.clone() {
            block_group[b as usize] = s.id;
        }
    }
    let mut expected = [0u64; 2];
    for spec in &specs {
        expected[block_group[part.block_of(spec.source) as usize] as usize] += 1;
    }
    assert!(expected.iter().all(|&e| e > 0), "both groups see work: {expected:?}");

    let (bm, batch_jobs) = coord(&g, &part, 2).run_batch_collect(&specs);
    assert_eq!(bm.completed(), 5);

    let mut addrs = Vec::new();
    let mut stacks = Vec::new();
    for _ in 0..2 {
        let (q, server) = start_group(&g);
        addrs.push(server.local_addr().to_string());
        stacks.push((q, server));
    }
    let router = router_over(addrs, part.clone(), nv);
    let raddr = router.local_addr().to_string();

    let client_specs = specs.clone();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&raddr, Duration::from_secs(5)).unwrap();
        let mut ids = Vec::new();
        for s in &client_specs {
            match c.submit(s.kind, s.source, None).unwrap() {
                Submitted::Accepted(id) => ids.push(id),
                Submitted::Rejected(r) => panic!("rejected: {r}"),
            }
        }
        let dones: Vec<_> = ids.iter().map(|_| c.wait_done().unwrap()).collect();
        let leftovers = c.quit().unwrap();
        assert!(leftovers.is_empty(), "no duplicate terminals after the expected ones");
        (ids, dones)
    });

    let (rstats, group_out) = std::thread::scope(|s| {
        let g = &g;
        let part = &part;
        let handles: Vec<_> = stacks
            .into_iter()
            .map(|(mut q, server)| {
                s.spawn(move || {
                    let mut c = coord(g, part, 2);
                    let (m, jobs) =
                        c.serve_notify_collect(&mut q, 0.0, |_| {}, |rec| server.notify_done(rec));
                    let stats = server.finish();
                    (m, jobs, stats)
                })
            })
            .collect();
        let rstats = router.serve();
        let group_out: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (rstats, group_out)
    });
    let (mut ids, dones) = client.join().unwrap();

    // exactly one terminal per ACKed job, all DONE
    assert_eq!(dones.len(), 5);
    assert!(dones.iter().all(|d| d.fail_reason.is_none()), "{dones:?}");
    let mut done_ids: Vec<u64> = dones.iter().map(|d| d.job_id).collect();
    ids.sort_unstable();
    done_ids.sort_unstable();
    assert_eq!(done_ids, ids, "terminals match ACKed ids one-to-one");
    for d in &dones {
        assert!(d.rounds > 0);
        assert!(d.queue_wait_s >= 0.0 && d.exec_s >= 0.0);
    }

    // router counters and source affinity
    assert_eq!((rstats.routed, rstats.done, rstats.failed, rstats.shed), (5, 5, 0, 0));
    for (i, gs) in rstats.groups.iter().enumerate() {
        assert!(!gs.down);
        assert_eq!(gs.submitted, expected[i], "group {i} got exactly its table share");
        assert_eq!(gs.done, expected[i]);
        assert_eq!(gs.failed, 0);
    }

    // every group drained cleanly and the merged results hit the
    // batch fixpoints
    let mut merged: Vec<JobState> = Vec::new();
    for (i, (m, jobs, stats)) in group_out.into_iter().enumerate() {
        assert_eq!(m.completed() as u64, expected[i]);
        assert!(m.drained);
        assert_eq!(stats.done_sent, expected[i]);
        assert_eq!(stats.done_dropped, 0);
        merged.extend(jobs);
    }
    assert_fixpoints_match(&batch_jobs, &merged);
}

/// A group that dies mid-stream: its job fails with `group_down`, the
/// other group's job completes, and every ACKed job still terminates
/// exactly once.
#[test]
fn router_fails_jobs_of_a_dead_group_and_completes_the_rest() {
    let (g, part) = setup(9);
    let nv = g.num_vertices() as u32;
    let shards = part.shard_by_bytes(2);
    let live_src = shards[0].vertices.start;
    let dead_src = shards[1].vertices.start;

    // group 0: a real stack
    let (mut queue, server) = start_group(&g);
    let live_addr = server.local_addr().to_string();
    // group 1: greets correctly, swallows poll traffic, then dies on
    // the first forwarded job
    let fake = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let fake_addr = fake.local_addr().unwrap().to_string();
    let fake_thread = std::thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let (mut s, _) = fake.accept().unwrap();
        s.write_all(format!("{}\n", proto::hello_line()).as_bytes()).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        loop {
            line.clear();
            if r.read_line(&mut line).unwrap() == 0 {
                return; // router gave up first
            }
            if line.starts_with("SUBMIT") {
                return; // drop the connection with the job un-ACKed
            }
        }
    });

    let router = router_over(vec![live_addr, fake_addr], part.clone(), nv);
    let raddr = router.local_addr().to_string();
    let client = std::thread::spawn(move || {
        let mut c = Client::connect_retry(&raddr, Duration::from_secs(5)).unwrap();
        let accept = |s: Submitted| match s {
            Submitted::Accepted(id) => id,
            Submitted::Rejected(r) => panic!("rejected: {r}"),
        };
        let id_live = accept(c.submit(JobKind::Bfs, live_src, None).unwrap());
        let id_dead = accept(c.submit(JobKind::Sssp, dead_src, None).unwrap());
        let a = c.wait_done().unwrap();
        let b = c.wait_done().unwrap();
        let leftovers = c.quit().unwrap();
        assert!(leftovers.is_empty(), "exactly one terminal per job");
        (id_live, id_dead, a, b)
    });

    let (rstats, m) = std::thread::scope(|s| {
        let g = &g;
        let part = &part;
        let gh = s.spawn(move || {
            let mut srv = coord(g, part, 1);
            let (m, _jobs) =
                srv.serve_notify_collect(&mut queue, 0.0, |_| {}, |rec| server.notify_done(rec));
            server.finish();
            m
        });
        let rstats = router.serve();
        (rstats, gh.join().unwrap())
    });
    fake_thread.join().unwrap();
    let (id_live, id_dead, a, b) = client.join().unwrap();

    let (done, fail) = if a.fail_reason.is_none() { (a, b) } else { (b, a) };
    assert_eq!(done.job_id, id_live, "the live group's job completed");
    assert!(done.fail_reason.is_none());
    assert!(done.rounds > 0);
    assert_eq!(fail.job_id, id_dead, "the dead group's job failed");
    assert_eq!(fail.fail_reason.as_deref(), Some("group_down"));

    assert_eq!((rstats.routed, rstats.done, rstats.failed), (2, 1, 1));
    assert!(!rstats.groups[0].down);
    assert!(rstats.groups[1].down, "the dead group is marked down");
    assert_eq!(rstats.groups[1].failed, 1);
    assert_eq!(m.completed(), 1, "the live group ran exactly its own job");
}
