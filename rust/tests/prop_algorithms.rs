//! Property tests for the algorithm layer: every delta program's
//! block-scheduled fixpoint must equal its classical reference,
//! independent of partition and scheduling policy.

mod common;

use tlsched::algorithms::DeltaProgram;
use common::{prop_check, random_graph, random_partition};
use tlsched::algorithms::sssp::dijkstra;
use tlsched::algorithms::wcc::union_find_components;
use tlsched::engine::{JobSpec, JobState, NoProbe};
use tlsched::scheduler::{run_to_convergence, Scheduler, SchedulerConfig, SchedulerKind};
use tlsched::trace::JobKind;

fn random_policy(rng: &mut tlsched::util::rng::Pcg32) -> SchedulerConfig {
    let kind = SchedulerKind::ALL[rng.gen_index(4)];
    let mut cfg = SchedulerConfig::new(kind);
    cfg.alpha = 0.2 + rng.gen_f64() * 0.8;
    cfg.epsilon_frac = rng.gen_f64() * 0.5;
    cfg.seed = rng.next_u64();
    if rng.gen_bool(0.5) {
        cfg.q_override = Some(1 + rng.gen_index(32));
    }
    cfg
}

#[test]
fn prop_sssp_any_schedule_matches_dijkstra() {
    prop_check("sssp vs dijkstra", 40, |rng| {
        let g = random_graph(rng);
        if g.num_vertices() == 0 {
            return Ok(());
        }
        let part = random_partition(&g, rng);
        let source = rng.gen_index(g.num_vertices()) as u32;
        let mut jobs = vec![JobState::new(0, JobSpec::new(JobKind::Sssp, source), &g)];
        let mut sched = Scheduler::new(random_policy(rng));
        run_to_convergence(&mut sched, &g, &part, &mut jobs, &mut NoProbe, 1_000_000);
        if !jobs[0].converged {
            return Err("did not converge".into());
        }
        let reference = dijkstra(&g, source);
        for (v, (a, b)) in jobs[0].values.iter().zip(&reference).enumerate() {
            match (a.is_finite(), b.is_finite()) {
                (true, true) => {
                    if (a - b).abs() > 1e-3 {
                        return Err(format!("v{v}: {a} vs dijkstra {b}"));
                    }
                }
                (fa, fb) if fa != fb => {
                    return Err(format!("v{v}: reachability mismatch {a} vs {b}"))
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bfs_hops_lower_bound_weighted_distance() {
    prop_check("bfs <= sssp/minw", 30, |rng| {
        let g = random_graph(rng);
        if g.num_vertices() == 0 || !g.is_weighted() {
            return Ok(());
        }
        let part = random_partition(&g, rng);
        let source = rng.gen_index(g.num_vertices()) as u32;
        let run = |kind: JobKind| {
            let mut jobs = vec![JobState::new(0, JobSpec::new(kind, source), &g)];
            let mut sched = Scheduler::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
            run_to_convergence(&mut sched, &g, &part, &mut jobs, &mut NoProbe, 1_000_000);
            jobs.remove(0).values
        };
        let hops = run(JobKind::Bfs);
        let dist = run(JobKind::Sssp);
        // min edge weight ≥ 1.0 in road grids → dist >= hops
        for (v, (h, d)) in hops.iter().zip(&dist).enumerate() {
            if h.is_finite() != d.is_finite() {
                return Err(format!("v{v}: reachability mismatch"));
            }
            if h.is_finite() && *d + 1e-3 < *h {
                return Err(format!("v{v}: weighted {d} < hops {h}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wcc_matches_union_find_on_symmetric_graphs() {
    prop_check("wcc vs union-find", 30, |rng| {
        // road grids and BA graphs are built symmetric
        let g = match rng.gen_range(2) {
            0 => tlsched::graph::generate::road_grid(
                3 + rng.gen_index(10),
                3 + rng.gen_index(10),
                rng.next_u64(),
            ),
            _ => tlsched::graph::generate::barabasi_albert(
                20 + rng.gen_index(200),
                2 + rng.gen_index(3),
                rng.next_u64(),
            ),
        };
        let part = random_partition(&g, rng);
        let mut jobs = vec![JobState::new(0, JobSpec::new(JobKind::Wcc, 0), &g)];
        let mut sched = Scheduler::new(random_policy(rng));
        run_to_convergence(&mut sched, &g, &part, &mut jobs, &mut NoProbe, 1_000_000);
        let labels = &jobs[0].values;
        let uf = union_find_components(&g);
        let n = g.num_vertices();
        for v in 0..n {
            for u in [0, n / 2, n - 1] {
                let same_uf = uf[v] == uf[u];
                let same_label = (labels[v] - labels[u]).abs() < 0.5;
                if same_uf != same_label {
                    return Err(format!("partition mismatch at ({v},{u})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pagerank_mass_bounded_and_nonnegative() {
    prop_check("pagerank mass", 30, |rng| {
        let g = random_graph(rng);
        let n = g.num_vertices();
        if n == 0 {
            return Ok(());
        }
        let part = random_partition(&g, rng);
        let mut jobs = vec![JobState::new(0, JobSpec::new(JobKind::PageRank, 0), &g)];
        let mut sched = Scheduler::new(random_policy(rng));
        run_to_convergence(&mut sched, &g, &part, &mut jobs, &mut NoProbe, 1_000_000);
        let total: f64 = jobs[0].values.iter().map(|v| *v as f64).sum();
        // fixpoint mass: n when no dangling vertices, less otherwise;
        // never exceeds n (plus epsilon slack)
        if total > n as f64 * 1.01 + 1.0 {
            return Err(format!("mass {total} exceeds n={n}"));
        }
        if jobs[0].values.iter().any(|v| *v < 0.0) {
            return Err("negative pagerank".into());
        }
        Ok(())
    });
}

#[test]
fn prop_tracked_summaries_match_scans() {
    // The perf-pass invariant: incremental ⟨Node_un, ΣP⟩ tracking must
    // equal a fresh scan after any amount of scheduled execution.
    prop_check("tracking consistency", 24, |rng| {
        let g = random_graph(rng);
        if g.num_vertices() == 0 {
            return Ok(());
        }
        let part = random_partition(&g, rng);
        let kind = JobKind::ALL[rng.gen_index(5)];
        let source = rng.gen_index(g.num_vertices()) as u32;
        let mut jobs = vec![JobState::new(0, JobSpec::new(kind, source), &g)];
        let mut cfg = random_policy(rng);
        cfg.incremental_summaries = true;
        if cfg.kind == SchedulerKind::Independent {
            cfg.kind = SchedulerKind::TwoLevel; // independent skips tracking
        }
        let mut sched = Scheduler::new(cfg);
        // run a few rounds (not to convergence — mid-flight state is the
        // interesting case)
        let rounds = 1 + rng.gen_index(5);
        for _ in 0..rounds {
            sched.round(&g, &part, &mut jobs, &mut NoProbe);
        }
        let job = &jobs[0];
        if job.tracking.is_none() {
            return Err("tracking was not enabled".into());
        }
        for b in &part.blocks {
            let scanned = job.block_summary(b);
            let tracked = job.summary_of(b);
            if tracked.node_un != scanned.node_un {
                return Err(format!(
                    "block {}: tracked node_un {} vs scanned {} ({})",
                    b.id,
                    tracked.node_un,
                    scanned.node_un,
                    job.program.name()
                ));
            }
            let tol = 1e-3 * (1.0 + scanned.p_sum.abs());
            if (tracked.p_sum - scanned.p_sum).abs() > tol {
                return Err(format!(
                    "block {}: tracked p_sum {} vs scanned {} ({})",
                    b.id,
                    tracked.p_sum,
                    scanned.p_sum,
                    job.program.name()
                ));
            }
        }
        if job.active_count_fast() != job.active_count() {
            return Err("active_count_fast mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_all_policies_agree_pairwise() {
    prop_check("policy invariance", 16, |rng| {
        let g = random_graph(rng);
        if g.num_vertices() < 4 {
            return Ok(());
        }
        let part = random_partition(&g, rng);
        let kinds = [JobKind::PageRank, JobKind::Sssp, JobKind::Bfs];
        let specs: Vec<JobSpec> = (0..3)
            .map(|i| JobSpec::new(kinds[i], rng.gen_index(g.num_vertices()) as u32))
            .collect();
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for kind in SchedulerKind::ALL {
            let mut jobs: Vec<JobState> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| JobState::new(i as u32, s.clone(), &g))
                .collect();
            let mut sched = Scheduler::new(SchedulerConfig::new(kind));
            run_to_convergence(&mut sched, &g, &part, &mut jobs, &mut NoProbe, 1_000_000);
            if !jobs.iter().all(|j| j.converged) {
                return Err(format!("{} failed to converge", kind.name()));
            }
            let values: Vec<Vec<f32>> = jobs.iter().map(|j| j.values.clone()).collect();
            match &reference {
                None => reference = Some(values),
                Some(r) => {
                    for (ji, (a, b)) in r.iter().zip(&values).enumerate() {
                        let tol = jobs[ji].program.value_tolerance();
                        for (vi, (x, y)) in a.iter().zip(b).enumerate() {
                            if x.is_finite() != y.is_finite() {
                                return Err(format!(
                                    "{}: job {ji} v{vi} reachability mismatch",
                                    kind.name()
                                ));
                            }
                            if x.is_finite() && (x - y).abs() > tol * 4.0 {
                                return Err(format!(
                                    "{}: job {ji} v{vi}: {x} vs {y}",
                                    kind.name()
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}
