//! Integration: AOT artifacts → PJRT runtime → batched backend, checked
//! against the pure-rust CPU engine. Skips (with a notice) when
//! `make artifacts` has not run.

use tlsched::engine::{JobSpec, JobState};
use tlsched::graph::{generate, BlockPartition};
use tlsched::runtime::{Manifest, XlaRuntime};
use tlsched::scheduler::{Scheduler, SchedulerConfig, SchedulerKind};
use tlsched::trace::JobKind;

fn artifacts_or_skip() -> Option<XlaRuntime> {
    let dir = Manifest::default_dir();
    if !Manifest::available(&dir) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaRuntime::new(&dir).expect("runtime"))
}

#[test]
fn pagerank_xla_matches_cpu_engine() {
    let Some(mut rt) = artifacts_or_skip() else { return };
    let g = generate::rmat(9, 8, 123); // 512 vertices <= N
    let part = BlockPartition::by_vertex_count(&g, 64);
    let mut sched = Scheduler::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    let epsilon = 1e-3f32;
    let res = tlsched::runtime::run_pagerank_batch(
        &mut rt, &g, &part, &mut sched, 3, epsilon, 10_000,
    )
    .expect("xla run");
    assert!(res.rounds > 0);
    assert!(res.blocks_scheduled > 0);

    // CPU reference: single job to fixpoint
    let mut job = JobState::new(0, JobSpec::new(JobKind::PageRank, 0), &g);
    tlsched::engine::run_single_to_convergence(&g, &part.blocks, &mut job, 100_000);

    // All three XLA lanes ran the same program → compare to the CPU
    // fixpoint. Both paths stop with per-vertex residual deltas below
    // epsilon, but the *unapplied* residual mass compounds differently
    // along each trajectory (Jacobi vs Gauss–Seidel), so the tolerance
    // is relative for hub vertices.
    for lane in 0..3 {
        for (v, (a, b)) in res.values[lane].iter().zip(&job.values).enumerate() {
            let tol = (0.02f32).max(0.01 * b.abs());
            assert!((a - b).abs() < tol, "lane {lane} vertex {v}: xla {a} vs cpu {b}");
        }
    }
}

#[test]
fn sssp_xla_matches_dijkstra() {
    let Some(mut rt) = artifacts_or_skip() else { return };
    let g = generate::road_grid(16, 16, 7); // 256 vertices, weighted
    let part = BlockPartition::by_vertex_count(&g, 64);
    let mut sched = Scheduler::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    let sources = [0u32, 100, 255];
    let res =
        tlsched::runtime::run_sssp_batch(&mut rt, &g, &part, &mut sched, &sources, 10_000)
            .expect("xla run");
    for (lane, &s) in sources.iter().enumerate() {
        let reference = tlsched::algorithms::sssp::dijkstra(&g, s);
        for (v, (a, b)) in res.values[lane].iter().zip(&reference).enumerate() {
            if b.is_finite() {
                assert!((a - b).abs() < 1e-2, "lane {lane} v{v}: xla {a} vs dijkstra {b}");
            } else {
                assert!(!a.is_finite(), "lane {lane} v{v}: expected unreachable");
            }
        }
    }
}

#[test]
fn kernel_and_reference_artifacts_agree() {
    let Some(mut rt) = artifacts_or_skip() else { return };
    let j = rt.manifest.jobs;
    let n = rt.manifest.n;
    // random-ish small inputs built deterministically
    let mut rng = tlsched::util::rng::Pcg32::seeded(5);
    let values: Vec<f32> = (0..j * n).map(|_| rng.gen_f32()).collect();
    let deltas: Vec<f32> = (0..j * n).map(|_| rng.gen_f32() * 0.1).collect();
    let mut adj = vec![0f32; n * n];
    for u in 0..n {
        // ~4 random out-edges per vertex
        let deg = 4;
        for _ in 0..deg {
            let v = rng.gen_index(n);
            adj[u * n + v] += 0.85 / deg as f32;
        }
    }
    let mask: Vec<f32> = (0..n).map(|_| if rng.gen_bool(0.5) { 1.0 } else { 0.0 }).collect();

    let mk = |data: &[f32], dims: &[i64]| tlsched::runtime::literal_f32(data, dims).unwrap();
    let dims_lane = [j as i64, n as i64];
    let dims_mat = [n as i64, n as i64];
    let dims_mask = [n as i64];

    let out_k = rt
        .execute(
            "pagerank_step",
            &[
                mk(&values, &dims_lane),
                mk(&deltas, &dims_lane),
                mk(&adj, &dims_mat),
                mk(&mask, &dims_mask),
            ],
        )
        .unwrap();
    let out_r = rt
        .execute(
            "pagerank_step_ref",
            &[
                mk(&values, &dims_lane),
                mk(&deltas, &dims_lane),
                mk(&adj, &dims_mat),
                mk(&mask, &dims_mask),
            ],
        )
        .unwrap();
    for (a, b) in out_k.iter().zip(&out_r) {
        let va = tlsched::runtime::literal_to_vec(a).unwrap();
        let vb = tlsched::runtime::literal_to_vec(b).unwrap();
        for (x, y) in va.iter().zip(&vb) {
            assert!((x - y).abs() < 1e-4, "kernel {x} vs ref {y}");
        }
    }
}

#[test]
fn warmup_compiles_all_entries() {
    let Some(mut rt) = artifacts_or_skip() else { return };
    rt.warmup().expect("warmup");
}
