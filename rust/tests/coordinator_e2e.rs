//! Integration tests over the coordinator: mixed workloads, trace
//! replay, cache-simulated runs, report export and the memory-
//! redundancy claim end-to-end.

mod common;

use common::prop_check;
use tlsched::coordinator::{Coordinator, CoordinatorConfig};
use tlsched::engine::{JobSpec, SimProbe};
use tlsched::graph::{generate, BlockPartition};
use tlsched::memsim::{AddressMap, HierarchyConfig, MemoryHierarchy};
use tlsched::scheduler::{SchedulerConfig, SchedulerKind};
use tlsched::trace::{self, JobKind, TraceConfig};
use tlsched::util::json::Json;

#[test]
fn mixed_batch_all_kinds_all_policies() {
    let g = generate::rmat(10, 8, 9);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for kind in SchedulerKind::ALL {
        let specs: Vec<JobSpec> = JobKind::ALL
            .iter()
            .enumerate()
            .map(|(i, k)| JobSpec::new(*k, (i * 173) as u32))
            .collect();
        let mut coord =
            Coordinator::new(&g, &part, CoordinatorConfig::new(SchedulerConfig::new(kind)));
        let m = coord.run_batch(&specs);
        assert_eq!(m.completed(), 5, "{}", kind.name());
        assert!(m.totals.updates > 0);
        assert!(m.rounds > 0);
    }
}

#[test]
fn report_json_parses_and_has_all_jobs() {
    let g = generate::erdos_renyi(512, 2048, 4);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let mut coord = Coordinator::new(
        &g,
        &part,
        CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel)),
    );
    let m = coord.run_batch(&[
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Bfs, 7),
    ]);
    let parsed = Json::parse(&m.to_json().to_string()).unwrap();
    assert_eq!(parsed.get("completed").unwrap().as_u64().unwrap(), 2);
    assert_eq!(parsed.get("jobs").unwrap().as_arr().unwrap().len(), 2);
    assert!(parsed.get("sharing_factor").unwrap().as_f64().unwrap() >= 1.0);
}

#[test]
fn trace_replay_preserves_job_results() {
    // jobs executed via trace replay must produce the same fixpoints as
    // batch execution
    let g = generate::road_grid(20, 20, 3);
    let part = BlockPartition::by_vertex_count(&g, 50);
    let tc = TraceConfig {
        days: 0.0005, // ~43 virtual seconds
        mean_rate_per_hour: 2000.0,
        mean_service_s: 5.0,
        num_vertices: g.num_vertices() as u32,
        ..Default::default()
    };
    let jobs = trace::generate(&tc);
    if jobs.is_empty() {
        return;
    }
    let mut coord = Coordinator::new(
        &g,
        &part,
        CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel)),
    );
    let m = coord.run_trace(&jobs, 2000.0);
    assert_eq!(m.completed(), jobs.len());
    for rec in &m.jobs {
        assert!(rec.rounds > 0);
        assert!(rec.finished_s >= rec.submitted_s);
    }
}

#[test]
fn memory_redundancy_claim_end_to_end() {
    // The paper's core claim, end to end: with >= 4 concurrent jobs on a
    // structure-overflow hierarchy, two-level DRAM traffic is lower than
    // independent execution's.
    let g = generate::rmat(12, 8, 77);
    let part = BlockPartition::by_vertex_count(&g, 256);
    let specs: Vec<JobSpec> =
        (0..8).map(|i| JobSpec::new(JobKind::ALL[i % 5], (i * 431) as u32)).collect();
    let mut dram = Vec::new();
    for kind in [SchedulerKind::Independent, SchedulerKind::TwoLevel] {
        let map = AddressMap::new(&g);
        let mut mem = MemoryHierarchy::new(HierarchyConfig::tiny());
        let mut probe = SimProbe { map: &map, mem: &mut mem };
        let mut ccfg = CoordinatorConfig::new(SchedulerConfig::new(kind));
        ccfg.max_rounds_per_job = 40;
        let mut coord = Coordinator::new(&g, &part, ccfg);
        let _ = coord.run_batch_probed(&specs, &mut probe);
        dram.push(mem.stats().dram_accesses);
    }
    assert!(
        (dram[1] as f64) < (dram[0] as f64) * 0.8,
        "two-level DRAM {} must be <80% of independent {}",
        dram[1],
        dram[0]
    );
}

#[test]
fn prop_admission_limit_never_exceeded() {
    prop_check("admission limit", 8, |rng| {
        let g = generate::erdos_renyi(256, 1024, rng.next_u64());
        let part = BlockPartition::by_vertex_count(&g, 64);
        let limit = 1 + rng.gen_index(4);
        let mut ccfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
        ccfg.max_concurrent = limit;
        let trace: Vec<trace::TraceJob> = (0..6)
            .map(|i| trace::TraceJob {
                id: i,
                arrival_s: 0.0,
                service_s: 1.0,
                kind: JobKind::ALL[rng.gen_index(5)],
                source: rng.gen_index(256) as u32,
            })
            .collect();
        let mut coord = Coordinator::new(&g, &part, ccfg);
        let m = coord.run_trace(&trace, 5000.0);
        if m.completed() != 6 {
            return Err(format!("completed {} of 6", m.completed()));
        }
        Ok(())
    });
}

#[test]
fn scheduling_overhead_is_reported() {
    let g = generate::rmat(11, 8, 21);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let mut coord = Coordinator::new(
        &g,
        &part,
        CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel)),
    );
    let m = coord.run_batch(&[
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Wcc, 0),
    ]);
    assert!(m.scheduling_s > 0.0, "MPDS planning time must be tracked");
    assert!(m.scheduling_s < m.wall_s, "planning cannot exceed wall time");
}
