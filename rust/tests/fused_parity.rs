//! Parity suite for the fused multi-job kernel and the parallel round
//! engine.
//!
//! Three layers of guarantees, each asserted bit-for-bit on the f32
//! value/delta lanes:
//!
//! 1. **Kernel parity** — `process_block_fused` produces exactly the
//!    lanes of per-job `process_block` dispatch, for every `JobKind`,
//!    mixed job sets, and the empty-block edge case. (Jobs own disjoint
//!    lanes, so hoisting the job loop inside the vertex loop preserves
//!    each job's f32 op sequence.)
//! 2. **Scheduler parity** — a sequential round with `fused = true` is
//!    bit-identical to the per-job reference round (`fused = false`)
//!    for every `SchedulerKind`.
//! 3. **Parallel determinism** — `round_parallel` is bit-identical
//!    across worker counts for every `SchedulerKind` (the sequential
//!    reference of the staged engine is the same code at `workers =
//!    1`); job-major policies are additionally bit-identical to the
//!    sequential `round`, and every parallel run converges to the same
//!    fixpoint as the sequential engine within program tolerance.

mod common;

use tlsched::algorithms::DeltaProgram;
use tlsched::engine::{
    process_block, process_block_fused, JobSpec, JobState, NoProbe,
};
use tlsched::graph::{generate, Block, BlockPartition, Graph};
use tlsched::scheduler::{
    run_to_convergence, run_to_convergence_parallel, Scheduler, SchedulerConfig,
    SchedulerKind,
};
use tlsched::trace::JobKind;
use tlsched::util::threadpool::ThreadPool;

fn mixed_jobs(g: &Graph, n: usize) -> Vec<JobState> {
    (0..n)
        .map(|i| {
            let kind = JobKind::ALL[i % 5];
            JobState::new(
                i as u32,
                JobSpec::new(kind, (i as u32 * 131) % g.num_vertices() as u32),
                g,
            )
        })
        .collect()
}

fn same_kind_jobs(g: &Graph, kind: JobKind, n: usize) -> Vec<JobState> {
    (0..n)
        .map(|i| {
            JobState::new(
                i as u32,
                JobSpec::new(kind, (i as u32 * 97) % g.num_vertices() as u32),
                g,
            )
        })
        .collect()
}

fn assert_lanes_eq(a: &[JobState], b: &[JobState], ctx: &str) {
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.values, y.values, "values diverge: {ctx} (job {})", x.id);
        assert_eq!(x.deltas, y.deltas, "deltas diverge: {ctx} (job {})", x.id);
        assert_eq!(x.updates, y.updates, "updates diverge: {ctx} (job {})", x.id);
        assert_eq!(x.edges, y.edges, "edges diverge: {ctx} (job {})", x.id);
    }
}

// ---- 1. kernel parity -------------------------------------------------

#[test]
fn kernel_parity_every_kind() {
    for kind in JobKind::ALL {
        // rmat (power-law) and road grid (weighted) exercise both edge
        // regimes
        for g in [generate::rmat(9, 8, 11), generate::road_grid(16, 16, 5)] {
            let part = BlockPartition::by_vertex_count(&g, 41); // odd size
            let mut a = same_kind_jobs(&g, kind, 4);
            let mut b = same_kind_jobs(&g, kind, 4);
            for _sweep in 0..3 {
                for blk in &part.blocks {
                    for j in a.iter_mut() {
                        process_block(&g, blk, j, &mut NoProbe);
                    }
                    process_block_fused(&g, blk, &mut b, &mut NoProbe);
                    assert_lanes_eq(&a, &b, &format!("{} block {}", kind.name(), blk.id));
                }
            }
        }
    }
}

#[test]
fn kernel_parity_mixed_kinds() {
    let g = generate::rmat(10, 8, 23);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let mut a = mixed_jobs(&g, 8);
    let mut b = mixed_jobs(&g, 8);
    for _sweep in 0..4 {
        for blk in &part.blocks {
            for j in a.iter_mut() {
                process_block(&g, blk, j, &mut NoProbe);
            }
            process_block_fused(&g, blk, &mut b, &mut NoProbe);
        }
        assert_lanes_eq(&a, &b, "mixed sweep");
    }
}

#[test]
fn kernel_empty_block_edge_case() {
    let g = generate::erdos_renyi(32, 100, 3);
    let empty = Block { id: 0, start: 7, end: 7, in_edges: 0, out_edges: 0 };
    let mut jobs = mixed_jobs(&g, 3);
    let before: Vec<(Vec<f32>, Vec<f32>)> =
        jobs.iter().map(|j| (j.values.clone(), j.deltas.clone())).collect();
    let s = process_block_fused(&g, &empty, &mut jobs, &mut NoProbe);
    assert_eq!(s.updates, 0);
    assert_eq!(s.jobs_dispatched, 0);
    for (j, (v, d)) in jobs.iter().zip(&before) {
        assert_eq!(&j.values, v);
        assert_eq!(&j.deltas, d);
    }
}

// ---- 2. scheduler parity: fused vs per-job reference ------------------

#[test]
fn scheduler_fused_matches_reference_every_policy() {
    let g = generate::rmat(10, 8, 37);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for kind in SchedulerKind::ALL {
        let mut jobs_fused = mixed_jobs(&g, 6);
        let mut jobs_ref = mixed_jobs(&g, 6);
        let cfg_fused = SchedulerConfig::new(kind);
        let mut cfg_ref = SchedulerConfig::new(kind);
        cfg_ref.fused = false;
        let mut sf = Scheduler::new(cfg_fused);
        let mut sr = Scheduler::new(cfg_ref);
        for round in 0..6 {
            let a = sf.round(&g, &part, &mut jobs_fused, &mut NoProbe);
            let b = sr.round(&g, &part, &mut jobs_ref, &mut NoProbe);
            assert_eq!(a, b, "{} round {round} stats", kind.name());
            assert_lanes_eq(
                &jobs_fused,
                &jobs_ref,
                &format!("{} round {round}", kind.name()),
            );
        }
    }
}

// ---- 3. parallel rounds -----------------------------------------------

#[test]
fn parallel_rounds_bit_identical_across_worker_counts() {
    let g = generate::rmat(10, 8, 41);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let pools = [
        ThreadPool::new(1),
        ThreadPool::new(2),
        ThreadPool::new(4),
        ThreadPool::new(8),
    ];
    for kind in SchedulerKind::ALL {
        let mut runs: Vec<(Vec<JobState>, Vec<tlsched::scheduler::RoundStats>)> = pools
            .iter()
            .map(|pool| {
                let mut jobs = mixed_jobs(&g, 6);
                let mut sched = Scheduler::new(SchedulerConfig::new(kind));
                let stats: Vec<_> = (0..6)
                    .map(|_| sched.round_parallel(&g, &part, &mut jobs, pool))
                    .collect();
                (jobs, stats)
            })
            .collect();
        let (ref_jobs, ref_stats) = runs.remove(0);
        for (w, (jobs, stats)) in runs.iter().enumerate() {
            assert_eq!(&ref_stats, stats, "{} stats differ at pool {w}", kind.name());
            assert_lanes_eq(&ref_jobs, jobs, &format!("{} pool {w}", kind.name()));
        }
    }
}

#[test]
fn convergence_bit_identical_at_workers_1_2_8() {
    // Full runs to convergence through the persistent executor: the
    // staged merge makes every round — and therefore the whole run —
    // bit-identical across worker counts, including the chunked
    // dispatch path at 8 workers on few-core CI machines.
    let g = generate::rmat(10, 8, 71);
    let part = BlockPartition::by_vertex_count(&g, 64);
    for kind in [SchedulerKind::RoundRobinBlocks, SchedulerKind::TwoLevel] {
        let mut reference: Option<(Vec<JobState>, usize)> = None;
        for workers in [1usize, 2, 8] {
            let pool = ThreadPool::new(workers);
            let mut jobs = mixed_jobs(&g, 6);
            let mut sched = Scheduler::new(SchedulerConfig::new(kind));
            let (rounds, stats) =
                run_to_convergence_parallel(&mut sched, &g, &part, &mut jobs, &pool, 1_000_000);
            assert!(stats.updates > 0, "{} w={workers}", kind.name());
            assert!(
                jobs.iter().all(|j| j.converged),
                "{} w={workers} did not converge",
                kind.name()
            );
            match &reference {
                None => reference = Some((jobs, rounds)),
                Some((r, ref_rounds)) => {
                    assert_lanes_eq(r, &jobs, &format!("{} w={workers}", kind.name()));
                    assert_eq!(
                        *ref_rounds, rounds,
                        "{} w={workers} round count",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn persistent_and_spawn_dispatch_bit_identical() {
    // The two scope_map dispatch modes (persistent workers with
    // chunked hand-off vs scoped spawn per call) must be semantically
    // interchangeable — rounds are a pure function of the plan.
    use tlsched::util::threadpool::ScopeDispatch;
    let g = generate::rmat(9, 8, 73);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let persistent = ThreadPool::with_dispatch(4, ScopeDispatch::Persistent);
    let spawn = ThreadPool::with_dispatch(4, ScopeDispatch::SpawnPerCall);
    for kind in SchedulerKind::ALL {
        let mut jobs_a = mixed_jobs(&g, 5);
        let mut jobs_b = mixed_jobs(&g, 5);
        let mut sa = Scheduler::new(SchedulerConfig::new(kind));
        let mut sb = Scheduler::new(SchedulerConfig::new(kind));
        for round in 0..5 {
            let a = sa.round_parallel(&g, &part, &mut jobs_a, &persistent);
            let b = sb.round_parallel(&g, &part, &mut jobs_b, &spawn);
            assert_eq!(a, b, "{} round {round}", kind.name());
            assert_lanes_eq(&jobs_a, &jobs_b, &format!("{} round {round}", kind.name()));
        }
    }
}

#[test]
fn parallel_fused_and_reference_kernels_bit_identical() {
    // The request path honors `fused = false` too: the staged engine
    // with per-job passes must equal the fused staged engine exactly.
    let g = generate::rmat(9, 8, 67);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let pool = ThreadPool::new(4);
    for kind in [SchedulerKind::RoundRobinBlocks, SchedulerKind::TwoLevel] {
        let mut jobs_fused = mixed_jobs(&g, 5);
        let mut jobs_ref = mixed_jobs(&g, 5);
        let cfg_fused = SchedulerConfig::new(kind);
        let mut cfg_ref = SchedulerConfig::new(kind);
        cfg_ref.fused = false;
        let mut sf = Scheduler::new(cfg_fused);
        let mut sr = Scheduler::new(cfg_ref);
        for round in 0..5 {
            let a = sf.round_parallel(&g, &part, &mut jobs_fused, &pool);
            let b = sr.round_parallel(&g, &part, &mut jobs_ref, &pool);
            assert_eq!(a, b, "{} round {round}", kind.name());
            assert_lanes_eq(
                &jobs_fused,
                &jobs_ref,
                &format!("{} parallel round {round}", kind.name()),
            );
        }
    }
}

#[test]
fn parallel_job_major_policies_match_sequential_bitwise() {
    // Independent and PrIter parallelize over jobs with disjoint lanes:
    // the parallel round must equal the sequential round exactly.
    let g = generate::rmat(9, 8, 47);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let pool = ThreadPool::new(4);
    for kind in [SchedulerKind::Independent, SchedulerKind::PrIterPerJob] {
        let mut jobs_seq = mixed_jobs(&g, 5);
        let mut jobs_par = mixed_jobs(&g, 5);
        let mut ss = Scheduler::new(SchedulerConfig::new(kind));
        let mut sp = Scheduler::new(SchedulerConfig::new(kind));
        for round in 0..5 {
            let a = ss.round(&g, &part, &mut jobs_seq, &mut NoProbe);
            let b = sp.round_parallel(&g, &part, &mut jobs_par, &pool);
            assert_eq!(a, b, "{} round {round}", kind.name());
            assert_lanes_eq(
                &jobs_seq,
                &jobs_par,
                &format!("{} round {round}", kind.name()),
            );
        }
    }
}

#[test]
fn parallel_fixpoints_match_sequential_every_policy() {
    // Block-major parallel rounds reorder cross-block propagation
    // (Jacobi within a round), so convergence paths differ — but the
    // delta-accumulative model guarantees the same fixpoints.
    let g = generate::rmat(10, 8, 53);
    let part = BlockPartition::by_vertex_count(&g, 64);
    let pool = ThreadPool::new(4);
    for kind in SchedulerKind::ALL {
        let mut jobs_seq = mixed_jobs(&g, 5);
        let mut ss = Scheduler::new(SchedulerConfig::new(kind));
        run_to_convergence(&mut ss, &g, &part, &mut jobs_seq, &mut NoProbe, 1_000_000);
        assert!(jobs_seq.iter().all(|j| j.converged), "{} seq", kind.name());

        let mut jobs_par = mixed_jobs(&g, 5);
        let mut sp = Scheduler::new(SchedulerConfig::new(kind));
        run_to_convergence_parallel(&mut sp, &g, &part, &mut jobs_par, &pool, 1_000_000);
        assert!(jobs_par.iter().all(|j| j.converged), "{} par", kind.name());

        for (a, b) in jobs_seq.iter().zip(&jobs_par) {
            let tol = a.program.value_tolerance();
            for (vi, (x, y)) in a.values.iter().zip(&b.values).enumerate() {
                assert_eq!(
                    x.is_finite(),
                    y.is_finite(),
                    "{}: job {} v{vi} reachability",
                    kind.name(),
                    a.id
                );
                if x.is_finite() {
                    assert!(
                        (x - y).abs() < tol * 4.0,
                        "{}: job {} v{vi}: {x} vs {y}",
                        kind.name(),
                        a.id
                    );
                }
            }
        }
    }
}

#[test]
fn parallel_rounds_keep_tracking_exact() {
    // Incremental ⟨Node_un, ΣP⟩ summaries must stay exact through the
    // staged merge (net-delta application + per-contribution
    // transitions).
    let g = generate::rmat(9, 8, 59);
    let part = BlockPartition::by_vertex_count(&g, 32);
    let pool = ThreadPool::new(4);
    for kind in [SchedulerKind::RoundRobinBlocks, SchedulerKind::TwoLevel] {
        let mut jobs = mixed_jobs(&g, 4);
        let mut sched = Scheduler::new(SchedulerConfig::new(kind));
        for _ in 0..4 {
            sched.round_parallel(&g, &part, &mut jobs, &pool);
        }
        for job in &jobs {
            assert!(job.tracking.is_some(), "{}", kind.name());
            for b in &part.blocks {
                let scanned = job.block_summary(b);
                let tracked = job.summary_of(b);
                assert_eq!(
                    tracked.node_un,
                    scanned.node_un,
                    "{}: job {} block {} node_un",
                    kind.name(),
                    job.id,
                    b.id
                );
                let tol = 1e-3 * (1.0 + scanned.p_sum.abs());
                assert!(
                    (tracked.p_sum - scanned.p_sum).abs() < tol,
                    "{}: job {} block {} p_sum {} vs {}",
                    kind.name(),
                    job.id,
                    b.id,
                    tracked.p_sum,
                    scanned.p_sum
                );
            }
            assert_eq!(job.active_count_fast(), job.active_count());
        }
    }
}

#[test]
fn prop_parallel_twolevel_deterministic_on_random_graphs() {
    common::prop_check("parallel determinism", 10, |rng| {
        let g = common::random_graph(rng);
        if g.num_vertices() < 8 {
            return Ok(());
        }
        let part = common::random_partition(&g, rng);
        let seed = rng.next_u64();
        let kinds = [JobKind::PageRank, JobKind::Sssp, JobKind::Bfs, JobKind::Wcc];
        let specs: Vec<JobSpec> = (0..4)
            .map(|i| JobSpec::new(kinds[i], rng.gen_index(g.num_vertices()) as u32))
            .collect();
        let mut lanes: Vec<Vec<Vec<f32>>> = Vec::new();
        for workers in [1usize, 3] {
            let pool = ThreadPool::new(workers);
            let mut jobs: Vec<JobState> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| JobState::new(i as u32, s.clone(), &g))
                .collect();
            let mut cfg = SchedulerConfig::new(SchedulerKind::TwoLevel);
            cfg.seed = seed;
            let mut sched = Scheduler::new(cfg);
            for _ in 0..5 {
                sched.round_parallel(&g, &part, &mut jobs, &pool);
            }
            lanes.push(jobs.iter().map(|j| j.deltas.clone()).collect());
        }
        if lanes[0] != lanes[1] {
            return Err("worker count changed round results".into());
        }
        Ok(())
    });
}
