//! End-to-end tests of the live serving mode: jobs submitted from
//! producer threads while other jobs are mid-iteration must reach the
//! same per-job fixpoints as an equivalent batch run, and the bounded
//! admission queue must shed (backpressure) at its bound.

use tlsched::coordinator::{
    AdmissionConfig, AdmissionPolicy, AdmissionQueue, Coordinator, CoordinatorConfig,
    JobRequest, SubmitError,
};
use tlsched::algorithms::DeltaProgram;
use tlsched::engine::JobSpec;
use tlsched::graph::{generate, BlockPartition};
use tlsched::scheduler::{SchedulerConfig, SchedulerKind};
use tlsched::trace::JobKind;

fn setup(scale: u32) -> (tlsched::graph::Graph, BlockPartition) {
    let g = generate::rmat(scale, 8, 77);
    let part = BlockPartition::by_vertex_count(&g, 64);
    (g, part)
}

fn coord<'g>(
    g: &'g tlsched::graph::Graph,
    part: &'g BlockPartition,
    workers: usize,
) -> Coordinator<'g> {
    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.workers = workers;
    Coordinator::new(g, part, cfg)
}

/// Jobs all submitted before the loop starts, FIFO admission, cap above
/// the job count: serve must replay the exact batch round sequence —
/// **bit-identical** per-job fixpoints, including the PageRank family.
#[test]
fn serve_prequeued_matches_batch_bitwise() {
    let (g, part) = setup(9);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Wcc, 0),
        JobSpec::new(JobKind::Bfs, 3),
        JobSpec::new(JobKind::Ppr, 17),
    ];

    let (bm, batch_jobs) = coord(&g, &part, 2).run_batch_collect(&specs);
    assert_eq!(bm.completed(), 5);

    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1000.0);
    for s in &specs {
        submitter.submit(JobRequest::new(s.kind, s.source)).unwrap();
    }
    drop(submitter);
    let mut server = coord(&g, &part, 2);
    let (sm, serve_jobs) = server.serve_collect(&mut queue, 0.0, |_| {});
    assert_eq!(sm.completed(), 5);
    assert_eq!(sm.rejected, 0);
    assert!(sm.drained, "clean shutdown marks the final snapshot drained");

    assert_eq!(batch_jobs.len(), serve_jobs.len());
    for (b, s) in batch_jobs.iter().zip(&serve_jobs) {
        assert_eq!(b.spec.kind, s.spec.kind);
        assert_eq!(b.updates, s.updates, "{}: work counters", b.program.name());
        assert_eq!(b.rounds, s.rounds, "{}: round counts", b.program.name());
        assert_eq!(b.values, s.values, "{}: values bit-identical", b.program.name());
        assert_eq!(b.deltas, s.deltas, "{}: deltas bit-identical", b.program.name());
    }
}

/// Jobs submitted from a second thread while earlier jobs are
/// mid-iteration join at round boundaries and still converge to the
/// batch fixpoints: exactly for the traversal programs (unique,
/// schedule-independent f32 fixpoint), within the program tolerance
/// for the PageRank family (join timing reorders f32 accumulation).
#[test]
fn serve_mid_flight_submissions_converge_to_batch_fixpoints() {
    let (g, part) = setup(11);
    let specs = vec![
        JobSpec::new(JobKind::PageRank, 0),
        JobSpec::new(JobKind::Sssp, 10),
        JobSpec::new(JobKind::Bfs, 3),
        JobSpec::new(JobKind::Wcc, 0),
    ];

    let (bm, batch_jobs) = coord(&g, &part, 2).run_batch_collect(&specs);
    assert_eq!(bm.completed(), 4);

    let (submitter, mut queue) = AdmissionQueue::live(&AdmissionConfig::default(), 1.0);
    let feeder_specs = specs.clone();
    let feeder = std::thread::spawn(move || {
        // first job immediately; the rest trickle in mid-flight
        submitter.submit(JobRequest::new(feeder_specs[0].kind, feeder_specs[0].source)).unwrap();
        for s in &feeder_specs[1..] {
            std::thread::sleep(std::time::Duration::from_millis(5));
            submitter.submit(JobRequest::new(s.kind, s.source)).unwrap();
        }
    });
    let mut server = coord(&g, &part, 2);
    let (sm, serve_jobs) = server.serve_collect(&mut queue, 0.0, |_| {});
    feeder.join().unwrap();
    assert_eq!(sm.completed(), 4);
    for rec in &sm.jobs {
        assert!(rec.queueing_s() >= 0.0);
        assert!(rec.finished_s >= rec.started_s);
    }

    assert_eq!(batch_jobs.len(), serve_jobs.len());
    for (b, s) in batch_jobs.iter().zip(&serve_jobs) {
        assert_eq!(b.spec.kind, s.spec.kind, "admission preserved submission order");
        assert!(s.converged);
        let exact = matches!(b.spec.kind, JobKind::Sssp | JobKind::Bfs | JobKind::Wcc);
        if exact {
            assert_eq!(b.values, s.values, "{}: exact fixpoint", b.program.name());
        } else {
            let tol = b.program.value_tolerance();
            for (x, y) in b.values.iter().zip(&s.values) {
                assert_eq!(x.is_finite(), y.is_finite());
                if x.is_finite() {
                    assert!(
                        (x - y).abs() < tol,
                        "{}: {x} vs {y}",
                        b.program.name()
                    );
                }
            }
        }
    }
}

/// The bounded submission queue sheds once full: with capacity 2 and 6
/// eager submissions, exactly 4 are rejected with `QueueFull`, and the
/// coordinator's metrics agree.
#[test]
fn serve_backpressure_rejects_at_queue_bound() {
    let (g, part) = setup(8);
    let acfg = AdmissionConfig { queue_capacity: 2, ..Default::default() };
    let (submitter, mut queue) = AdmissionQueue::live(&acfg, 1000.0);
    let mut accepted = 0;
    let mut rejected = 0;
    for i in 0..6u32 {
        match submitter.submit(JobRequest::new(JobKind::Bfs, i * 7)) {
            Ok(_) => accepted += 1,
            Err(SubmitError::QueueFull) => rejected += 1,
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert_eq!((accepted, rejected), (2, 4));
    drop(submitter);

    let mut server = coord(&g, &part, 1);
    let m = server.serve(&mut queue, 0.0, |_| {});
    assert_eq!(m.completed(), 2);
    assert_eq!(m.rejected, 4);
    assert!(m.drained, "shed jobs don't block the drain");
}

/// With an admission limit of 1, queued jobs wait for the resident job
/// to retire; queue-wait accounting reflects the serialization and the
/// SLO policy still completes everything.
#[test]
fn serve_serializes_under_admission_limit_and_accounts_queue_wait() {
    let (g, part) = setup(9);
    let acfg = AdmissionConfig { policy: AdmissionPolicy::Slo, ..Default::default() };
    let (submitter, mut queue) = AdmissionQueue::live(&acfg, 1000.0);
    // shortest deadline last: SLO order must not starve anyone
    submitter.submit(JobRequest::new(JobKind::PageRank, 0).deadline(Some(9000.0))).unwrap();
    submitter.submit(JobRequest::new(JobKind::Bfs, 3).deadline(Some(5000.0))).unwrap();
    submitter.submit(JobRequest::new(JobKind::Sssp, 10).deadline(Some(1000.0))).unwrap();
    drop(submitter);

    let mut cfg = CoordinatorConfig::new(SchedulerConfig::new(SchedulerKind::TwoLevel));
    cfg.max_concurrent = 1;
    let mut server = Coordinator::new(&g, &part, cfg);
    let m = server.serve(&mut queue, 0.0, |_| {});
    assert_eq!(m.completed(), 3);
    // serialized: exactly one job resident at a time ⇒ later starts
    // come after earlier finishes (records are in retirement order)
    for w in m.jobs.windows(2) {
        assert!(w[1].started_s >= w[0].finished_s - 1e-9);
    }
    // someone necessarily waited behind the first job
    assert!(m.p95_queue_wait_s() > 0.0);
}
