//! Property tests for the telemetry layer (DESIGN.md §12): fixed-bucket
//! histogram correctness (`obs::hist`) and Prometheus text exposition
//! conformance (`obs::prom`). These are the properties the hist/prom
//! module docs point at: bucket-index monotonicity, exact count/sum
//! conservation, merge associativity/commutativity, the quantile
//! bucket-width error bound, label-escape round-tripping, and the
//! format-level invariants every scraper relies on (one HELP/TYPE per
//! family, unique series, parseable values, cumulative buckets with
//! `le="+Inf"` equal to `_count`).

mod common;

use common::prop_check;
use std::collections::{BTreeMap, BTreeSet};
use tlsched::obs::hist::{bucket_index, HistogramData, DEFAULT_BOUNDS};
use tlsched::obs::prom::{escape_label, merge_scrapes, render};
use tlsched::obs::registry::Registry;
use tlsched::util::rng::Pcg32;

/// Log-uniform sample over 1e-4 .. 1e3 seconds: spans below the first
/// bound (0.001), across every finite bucket, and above the last bound
/// (100.0) into the +Inf bucket.
fn random_value(rng: &mut Pcg32) -> f64 {
    10f64.powf(rng.gen_f64() * 7.0 - 4.0)
}

fn random_values(rng: &mut Pcg32, n: usize) -> Vec<f64> {
    (0..n).map(|_| random_value(rng)).collect()
}

fn random_hist(rng: &mut Pcg32) -> HistogramData {
    let mut h = HistogramData::new();
    for _ in 0..rng.gen_index(64) {
        h.record(random_value(rng));
    }
    h
}

#[test]
fn prop_bucket_index_is_monotone_and_total() {
    prop_check("bucket_index monotone/total", 512, |rng| {
        let a = 10f64.powf(rng.gen_f64() * 8.0 - 5.0);
        let b = 10f64.powf(rng.gen_f64() * 8.0 - 5.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (il, ih) = (bucket_index(DEFAULT_BOUNDS, lo), bucket_index(DEFAULT_BOUNDS, hi));
        if il > ih {
            return Err(format!("index decreased: {lo} -> {il}, {hi} -> {ih}"));
        }
        if ih > DEFAULT_BOUNDS.len() {
            return Err(format!("index {ih} past the +Inf bucket"));
        }
        // the chosen bucket's bounds must actually contain the value
        let lo_bound = if il == 0 { f64::NEG_INFINITY } else { DEFAULT_BOUNDS[il - 1] };
        let hi_bound =
            if il < DEFAULT_BOUNDS.len() { DEFAULT_BOUNDS[il] } else { f64::INFINITY };
        if !(lo > lo_bound && lo <= hi_bound) {
            return Err(format!("{lo} not in bucket {il} = ({lo_bound}, {hi_bound}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_count_and_sum_are_conserved() {
    prop_check("count/sum conservation", 256, |rng| {
        let n = rng.gen_index(256);
        let samples = random_values(rng, n);
        let mut h = HistogramData::new();
        let mut exact_sum = 0.0;
        for &v in &samples {
            h.record(v);
            exact_sum += v;
        }
        if h.count != n as u64 {
            return Err(format!("count {} != {n}", h.count));
        }
        if h.buckets.iter().sum::<u64>() != h.count {
            return Err("bucket totals do not add up to count".into());
        }
        // record() accumulates in the same order as the fold above, so
        // the float sums are bit-identical, not merely close.
        if h.sum != exact_sum {
            return Err(format!("sum {} != exact {exact_sum}", h.sum));
        }
        // splitting the stream and merging back conserves everything
        let k = rng.gen_index(n + 1);
        let mut h1 = HistogramData::new();
        let mut h2 = HistogramData::new();
        for &v in &samples[..k] {
            h1.record(v);
        }
        for &v in &samples[k..] {
            h2.record(v);
        }
        h1.merge(&h2);
        if h1.buckets != h.buckets || h1.count != h.count {
            return Err(format!("merge of split at {k} lost samples"));
        }
        Ok(())
    });
}

#[test]
fn prop_merge_is_associative_and_commutative() {
    prop_check("merge assoc/commut", 256, |rng| {
        let (a, b, c) = (random_hist(rng), random_hist(rng), random_hist(rng));
        // (a ∪ b) ∪ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ∪ (b ∪ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        if left.buckets != right.buckets || left.count != right.count {
            return Err("merge is not associative on buckets/count".into());
        }
        if (left.sum - right.sum).abs() > 1e-9 * (1.0 + left.sum.abs()) {
            return Err(format!("sums diverge: {} vs {}", left.sum, right.sum));
        }
        // a ∪ b == b ∪ a
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        if ab.buckets != ba.buckets || ab.count != ba.count {
            return Err("merge is not commutative on buckets/count".into());
        }
        if (ab.sum - ba.sum).abs() > 1e-9 * (1.0 + ab.sum.abs()) {
            return Err(format!("sums diverge: {} vs {}", ab.sum, ba.sum));
        }
        Ok(())
    });
}

#[test]
fn prop_quantile_estimate_stays_in_exact_rank_bucket() {
    prop_check("quantile bucket-width bound", 256, |rng| {
        let n = 1 + rng.gen_index(512);
        let samples = random_values(rng, n);
        let mut h = HistogramData::new();
        for &v in &samples {
            h.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = (rng.gen_index(100) as f64 + 1.0) / 100.0; // (0, 1]
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        let exact = sorted[rank - 1];
        let est = h.quantile(q);
        let i = bucket_index(DEFAULT_BOUNDS, exact);
        if i >= DEFAULT_BOUNDS.len() {
            // +Inf bucket: the estimate clamps to the last finite bound
            let last = *DEFAULT_BOUNDS.last().unwrap();
            if est != last {
                return Err(format!("+Inf-bucket sample: est {est} != clamp {last}"));
            }
        } else {
            let lo = if i == 0 { 0.0 } else { DEFAULT_BOUNDS[i - 1] };
            let hi = DEFAULT_BOUNDS[i];
            if !(est > lo && est <= hi) {
                return Err(format!(
                    "q={q} n={n}: est {est} outside exact-rank bucket ({lo}, {hi}], exact {exact}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_label_escaping_round_trips() {
    fn unescape(s: &str) -> String {
        let mut out = String::new();
        let mut it = s.chars();
        while let Some(c) = it.next() {
            if c != '\\' {
                out.push(c);
                continue;
            }
            match it.next() {
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                Some('n') => out.push('\n'),
                other => {
                    out.push('\\');
                    if let Some(o) = other {
                        out.push(o);
                    }
                }
            }
        }
        out
    }
    prop_check("label escape round-trip", 512, |rng| {
        let alphabet = ['a', 'z', '"', '\\', '\n', ' ', '{', '}', ',', '='];
        let len = rng.gen_index(24);
        let raw: String = (0..len).map(|_| alphabet[rng.gen_index(alphabet.len())]).collect();
        let esc = escape_label(&raw);
        if esc.contains('\n') {
            return Err(format!("raw newline survived escaping: {raw:?}"));
        }
        if unescape(&esc) != raw {
            return Err(format!("round-trip failed: {raw:?} -> {esc:?}"));
        }
        Ok(())
    });
}

/// A registry exercising every instrument kind, an escaped label value
/// and the four stage histograms, with randomised values.
fn random_registry(rng: &mut Pcg32) -> Registry {
    let r = Registry::new();
    r.counter("jobs_total", "jobs seen").add(u64::from(rng.next_u32()));
    r.gauge("queue_depth", "queue depth").set(rng.gen_f64() * 100.0 - 50.0);
    let nasty = ["plain", "w\"quote", "back\\slash", "new\nline"];
    r.gauge_with("labeled", &[("path", nasty[rng.gen_index(nasty.len())])], "escaped label")
        .set(rng.gen_f64());
    for stage in ["plan", "execute", "merge", "exchange"] {
        let h = r.histogram_with("stage_seconds", &[("stage", stage)], "stage durations");
        for _ in 0..rng.gen_index(40) {
            h.record(random_value(rng));
        }
    }
    r
}

/// Split a `{…}` label body into (labels without `le`, parsed le bound).
fn split_le(body: &str) -> Option<(String, f64)> {
    let start = body.find("le=\"")?;
    let rest = &body[start + 4..];
    let end = rest.find('"')?;
    let le = match &rest[..end] {
        "+Inf" => f64::INFINITY,
        s => s.parse().ok()?,
    };
    let mut others = String::new();
    others.push_str(body[..start].trim_end_matches(','));
    others.push_str(rest[end + 1..].trim_start_matches(','));
    Some((others, le))
}

/// Conformance checker for the Prometheus text format (version 0.0.4):
/// exactly one HELP and TYPE per family, known types only, unique
/// series, every value parseable as f64 (incl. +Inf/-Inf/NaN), every
/// sample covered by a TYPE line, and histogram series cumulative with
/// `le="+Inf"` equal to `_count` and a `_sum` present.
fn check_exposition(text: &str) -> Result<(), String> {
    let mut type_of: BTreeMap<&str, &str> = BTreeMap::new();
    let mut helped: BTreeSet<&str> = BTreeSet::new();
    let mut series: BTreeMap<String, f64> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) =
                rest.split_once(' ').ok_or_else(|| format!("bad TYPE line: {line}"))?;
            if !matches!(kind, "counter" | "gauge" | "histogram") {
                return Err(format!("unknown type {kind} for {name}"));
            }
            if type_of.insert(name, kind).is_some() {
                return Err(format!("duplicate TYPE for {name}"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, _) =
                rest.split_once(' ').ok_or_else(|| format!("bad HELP line: {line}"))?;
            if !helped.insert(name) {
                return Err(format!("duplicate HELP for {name}"));
            }
            continue;
        }
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let sp = line.rfind(' ').ok_or_else(|| format!("sample without value: {line}"))?;
        let (name, value) = (&line[..sp], &line[sp + 1..]);
        if value.parse::<f64>().is_err() {
            return Err(format!("unparseable value {value:?} in: {line}"));
        }
        if series.insert(name.to_string(), value.parse().unwrap()).is_some() {
            return Err(format!("duplicate series {name}"));
        }
        let bare = name.split('{').next().unwrap();
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|s| {
                bare.strip_suffix(s).filter(|b| type_of.get(b).copied() == Some("histogram"))
            })
            .unwrap_or(bare);
        if !type_of.contains_key(family) {
            return Err(format!("sample {name} has no TYPE line"));
        }
    }
    for (fam, kind) in &type_of {
        if *kind != "histogram" {
            continue;
        }
        let bucket_name = format!("{fam}_bucket");
        let mut groups: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
        for (name, &v) in &series {
            if name.split('{').next().unwrap() != bucket_name {
                continue;
            }
            let open = name.find('{').ok_or_else(|| format!("bucket without le: {name}"))?;
            let (others, le) = split_le(&name[open + 1..name.len() - 1])
                .ok_or_else(|| format!("bucket without le: {name}"))?;
            groups.entry(others).or_default().push((le, v));
        }
        if groups.is_empty() {
            return Err(format!("histogram {fam} has no bucket series"));
        }
        for (others, mut pts) in groups {
            pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in pts.windows(2) {
                if w[1].1 < w[0].1 {
                    return Err(format!("{fam}{{{others}}}: buckets are not cumulative"));
                }
            }
            let (last_le, last_v) = *pts.last().unwrap();
            if last_le != f64::INFINITY {
                return Err(format!("{fam}{{{others}}}: missing le=\"+Inf\" bucket"));
            }
            let suffixed = |suf: &str| {
                if others.is_empty() {
                    format!("{fam}{suf}")
                } else {
                    format!("{fam}{suf}{{{others}}}")
                }
            };
            let count_name = suffixed("_count");
            let count =
                *series.get(&count_name).ok_or_else(|| format!("missing {count_name}"))?;
            if last_v != count {
                return Err(format!("{fam}: +Inf bucket {last_v} != count {count}"));
            }
            let sum_name = suffixed("_sum");
            if !series.contains_key(&sum_name) {
                return Err(format!("missing {sum_name}"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_rendered_exposition_conforms() {
    prop_check("exposition conformance", 64, |rng| {
        check_exposition(&render(&random_registry(rng).snapshot()))
    });
}

#[test]
fn prop_merged_scrapes_conform_and_carry_group_labels() {
    prop_check("merged-scrape conformance", 64, |rng| {
        let a = render(&random_registry(rng).snapshot());
        let b = render(&random_registry(rng).snapshot());
        let merged = merge_scrapes(&[("0".to_string(), a), ("1".to_string(), b)]);
        check_exposition(&merged)?;
        for line in merged.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if !line.contains("group=\"") {
                return Err(format!("merged sample without group label: {line}"));
            }
        }
        Ok(())
    });
}

#[test]
fn check_exposition_rejects_malformed_text() {
    // the checker itself must catch format violations, or the property
    // tests above prove nothing
    assert!(check_exposition("# TYPE a counter\n# TYPE a counter\na 1\n").is_err());
    assert!(check_exposition("# TYPE a counter\na 1\na 1\n").is_err());
    assert!(check_exposition("a 1\n").is_err(), "sample without TYPE");
    assert!(check_exposition("# TYPE a counter\na one\n").is_err(), "bad value");
    assert!(
        check_exposition(
            "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 2\nh_sum 1\nh_count 3\n"
        )
        .is_err(),
        "+Inf bucket must equal count"
    );
    assert!(
        check_exposition(
            "# TYPE h histogram\nh_bucket{le=\"1\"} 2\nh_sum 1\nh_count 2\n"
        )
        .is_err(),
        "missing +Inf bucket"
    );
}
