"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes and inputs; every case asserts the Pallas
kernels (interpret mode) match the pure-jnp oracles in ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.pagerank_block import matmul_tiled, pagerank_step
from compile.kernels.sssp_block import minplus_tiled, sssp_step

jax.config.update("jax_platform_name", "cpu")

# interpret-mode Pallas is slow; keep hypothesis examples modest
COMMON = dict(deadline=None, max_examples=12)


def rand(key, shape, lo=0.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def random_adj_norm(key, n, density=0.05, damping=0.85):
    k1, k2 = jax.random.split(key)
    edges = jax.random.bernoulli(k1, density, (n, n)).astype(jnp.float32)
    outdeg = jnp.maximum(edges.sum(axis=1, keepdims=True), 1.0)
    return damping * edges / outdeg


def random_weights(key, n, density=0.1):
    k1, k2 = jax.random.split(key)
    edges = jax.random.bernoulli(k1, density, (n, n))
    w = rand(k2, (n, n), 1.0, 10.0)
    return jnp.where(edges, w, ref.BIG)


def random_mask(key, n, p=0.5):
    return jax.random.bernoulli(key, p, (n,)).astype(jnp.float32)


# ---------------------------------------------------------------- matmul


@settings(**COMMON)
@given(
    j=st.sampled_from([1, 4, 8]),
    kn=st.sampled_from([(64, 64), (128, 64), (64, 128)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_matches_dot(j, kn, seed):
    k_dim, n = kn
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (j, k_dim), -1.0, 1.0)
    a = rand(k2, (k_dim, n), -1.0, 1.0)
    got = matmul_tiled(x, a, tile_n=32, tile_k=32)
    want = x @ a
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_tile_shape_mismatch_raises():
    x = jnp.zeros((2, 48))
    a = jnp.zeros((48, 64))
    with pytest.raises(AssertionError):
        matmul_tiled(x, a, tile_n=32, tile_k=32)  # 48 % 32 != 0


@pytest.mark.parametrize("tile", [16, 32, 64])
def test_matmul_tile_size_invariance(tile):
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (8, 64), -2.0, 2.0)
    a = rand(k2, (64, 64), -2.0, 2.0)
    got = matmul_tiled(x, a, tile_n=tile, tile_k=tile)
    np.testing.assert_allclose(got, x @ a, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- minplus


@settings(**COMMON)
@given(
    j=st.sampled_from([1, 4, 8]),
    n=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_minplus_tiled_matches_dense(j, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (j, n), 0.0, 50.0)
    a = random_weights(k2, n)
    got = minplus_tiled(x, a, tile_n=32, tile_k=32)
    want = jnp.minimum(jnp.min(x[:, :, None] + a[None, :, :], axis=1), ref.BIG)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_minplus_identity_on_no_edges():
    x = jnp.zeros((2, 64), jnp.float32)
    a = jnp.full((64, 64), ref.BIG, jnp.float32)
    got = minplus_tiled(x, a, tile_n=32, tile_k=32)
    assert bool(jnp.all(got >= ref.BIG * 0.99))


# ---------------------------------------------------------------- steps


@settings(**COMMON)
@given(
    j=st.sampled_from([1, 8]),
    n=st.sampled_from([64, 128]),
    mask_p=st.sampled_from([0.0, 0.3, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pagerank_step_matches_ref(j, n, mask_p, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    values = rand(k1, (j, n))
    deltas = rand(k2, (j, n), 0.0, 0.15)
    adj = random_adj_norm(k3, n)
    mask = random_mask(k4, n, mask_p)
    got_v, got_d = pagerank_step(values, deltas, adj, mask, tile=32)
    want_v, want_d = ref.pagerank_step_ref(values, deltas, adj, mask)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-6)


@settings(**COMMON)
@given(
    j=st.sampled_from([1, 8]),
    n=st.sampled_from([64, 128]),
    mask_p=st.sampled_from([0.0, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sssp_step_matches_ref(j, n, mask_p, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    dist = jnp.where(
        jax.random.bernoulli(k1, 0.3, (j, n)), rand(k1, (j, n), 0.0, 20.0), ref.BIG
    )
    w = random_weights(k2, n)
    mask = random_mask(k3, n, mask_p)
    got = sssp_step(dist, w, mask, tile=32)
    want = ref.sssp_step_ref(dist, w, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pagerank_zero_mask_is_identity():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    values = rand(k1, (4, 64))
    deltas = rand(k2, (4, 64))
    adj = random_adj_norm(k3, 64)
    mask = jnp.zeros((64,), jnp.float32)
    v, d = pagerank_step(values, deltas, adj, mask, tile=32)
    np.testing.assert_allclose(v, values)
    np.testing.assert_allclose(d, deltas)


def test_pagerank_mass_conservation_full_mask():
    """With a stochastic-ish adj (all outdeg >= 1), one full-mask step
    moves exactly `damping` of the consumed delta mass."""
    n = 64
    key = jax.random.PRNGKey(3)
    adj = random_adj_norm(key, n, density=0.2, damping=0.85)
    # ensure every row has at least one edge: rows with zero sum get self-loop
    rowsum = adj.sum(axis=1)
    adj = jnp.where((rowsum[:, None] == 0) & (jnp.eye(n) > 0), 0.85, adj)
    values = jnp.zeros((1, n), jnp.float32)
    deltas = jnp.full((1, n), 0.15, jnp.float32)
    mask = jnp.ones((n,), jnp.float32)
    v, d = pagerank_step(values, deltas, adj, mask, tile=32)
    np.testing.assert_allclose(float(v.sum()), 0.15 * n, rtol=1e-5)
    np.testing.assert_allclose(float(d.sum()), 0.85 * 0.15 * n, rtol=1e-4)


def test_sssp_converges_on_path_graph():
    """Iterating the step must converge to true shortest paths."""
    n = 64
    w = jnp.full((n, n), ref.BIG, jnp.float32)
    for i in range(n - 1):
        w = w.at[i, i + 1].set(1.0)
    dist = jnp.full((1, n), ref.BIG, jnp.float32).at[0, 0].set(0.0)
    mask = jnp.ones((n,), jnp.float32)
    for _ in range(n):
        nd = sssp_step(dist, w, mask, tile=32)
        if bool(jnp.all(nd == dist)):
            break
        dist = nd
    np.testing.assert_allclose(dist[0], jnp.arange(n, dtype=jnp.float32))
