"""L2 model semantics: iterating the step functions must converge to
the classical fixpoints (power-iteration PageRank, Bellman–Ford SSSP),
including under partial (masked) scheduling — the property MPDS relies
on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import pagerank_step_model, sssp_step_model

jax.config.update("jax_platform_name", "cpu")

N = 64
J = 4


@pytest.fixture(scope="module")
def graph():
    """Small random digraph with all out-degrees >= 1."""
    key = jax.random.PRNGKey(42)
    edges = jax.random.bernoulli(key, 0.08, (N, N))
    edges = edges.at[jnp.arange(N), (jnp.arange(N) + 1) % N].set(True)  # cycle
    outdeg = edges.sum(axis=1)
    adj_norm = 0.85 * edges / outdeg[:, None]
    weights = jnp.where(edges, 1.0 + 9.0 * jax.random.uniform(key, (N, N)), ref.BIG)
    return edges, adj_norm.astype(jnp.float32), weights.astype(jnp.float32)


def run_pagerank(adj_norm, mask_fn, max_rounds=2000, eps=1e-6):
    values = jnp.zeros((J, N), jnp.float32)
    deltas = jnp.full((J, N), 0.15, jnp.float32)
    for r in range(max_rounds):
        mask = mask_fn(r)
        values, deltas = pagerank_step_model(values, deltas, adj_norm, mask)
        if float(jnp.abs(deltas).max()) < eps:
            break
    return values


def test_pagerank_full_mask_matches_power_iteration(graph):
    edges, adj_norm, _ = graph
    got = run_pagerank(adj_norm, lambda r: jnp.ones((N,), jnp.float32))
    # power iteration on the same operator
    p = jnp.zeros((N,), jnp.float32)
    d = jnp.full((N,), 0.15, jnp.float32)
    for _ in range(2000):
        p = p + d
        d = d @ adj_norm
    for j in range(J):
        np.testing.assert_allclose(got[j], p, rtol=1e-3, atol=1e-4)


def test_pagerank_partial_masks_same_fixpoint(graph):
    """Alternating half-masks must reach the same fixpoint as full
    sweeps — the delta-accumulative model is schedule-independent."""
    edges, adj_norm, _ = graph
    full = run_pagerank(adj_norm, lambda r: jnp.ones((N,), jnp.float32))
    half0 = jnp.concatenate([jnp.ones(N // 2), jnp.zeros(N // 2)]).astype(jnp.float32)
    half1 = 1.0 - half0
    partial = run_pagerank(adj_norm, lambda r: half0 if r % 2 == 0 else half1)
    np.testing.assert_allclose(partial, full, rtol=5e-3, atol=5e-4)


def test_sssp_converges_to_bellman_ford(graph):
    edges, _, weights = graph
    dist = jnp.full((J, N), ref.BIG, jnp.float32)
    sources = [0, 7, 13, 21]
    for j, s in enumerate(sources):
        dist = dist.at[j, s].set(0.0)
    mask = jnp.ones((N,), jnp.float32)
    for _ in range(N + 1):
        nd = sssp_step_model(dist, weights, mask)
        if bool(jnp.all(nd == dist)):
            break
        dist = nd
    # classical Bellman-Ford per source
    w = np.where(np.asarray(edges), np.asarray(weights), np.inf)
    for j, s in enumerate(sources):
        bf = np.full(N, np.inf)
        bf[s] = 0.0
        for _ in range(N):
            cand = (bf[:, None] + w).min(axis=0)
            bf = np.minimum(bf, cand)
        got = np.asarray(dist[j])
        reach = np.isfinite(bf)
        np.testing.assert_allclose(got[reach], bf[reach], rtol=1e-5, atol=1e-3)
        assert (got[~reach] >= ref.BIG * 0.99).all()


def test_sssp_partial_masks_same_fixpoint(graph):
    edges, _, weights = graph
    mask_full = jnp.ones((N,), jnp.float32)
    half0 = jnp.concatenate([jnp.ones(N // 2), jnp.zeros(N // 2)]).astype(jnp.float32)
    half1 = 1.0 - half0

    def run(mask_fn, rounds):
        dist = jnp.full((1, N), ref.BIG, jnp.float32).at[0, 0].set(0.0)
        for r in range(rounds):
            dist = sssp_step_model(dist, weights, mask_fn(r))
        return dist

    full = run(lambda r: mask_full, N)
    partial = run(lambda r: half0 if r % 2 == 0 else half1, 4 * N)
    np.testing.assert_allclose(partial, full, rtol=1e-5, atol=1e-3)
