"""AOT pipeline: lowering produces loadable HLO text and an accurate
manifest; shapes stay configurable."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--jobs", "2",
         "--n", "128", "--tile", "32"],
        cwd=PYDIR,
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_lists_all_entries(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    assert m["jobs"] == 2
    assert m["n"] == 128
    assert m["tile"] == 32
    names = {e["name"] for e in m["entries"]}
    assert names == {"pagerank_step", "pagerank_step_ref", "sssp_step", "sssp_step_ref"}
    for e in m["entries"]:
        assert (artifacts / e["file"]).exists()
        assert e["hlo_bytes"] > 0


def test_hlo_text_is_parseable_module(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    for e in m["entries"]:
        text = (artifacts / e["file"]).read_text()
        assert text.startswith("HloModule"), f"{e['name']} missing HloModule header"
        assert "ENTRY" in text
        # the interchange contract: text, not serialized proto
        assert "\x00" not in text


def test_entry_arity_matches_manifest(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    by_name = {e["name"]: e for e in m["entries"]}
    assert by_name["pagerank_step"]["inputs"] == 4
    assert by_name["pagerank_step"]["outputs"] == 2
    assert by_name["sssp_step"]["inputs"] == 3
    assert by_name["sssp_step"]["outputs"] == 1


def test_bad_tile_rejected():
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", "/tmp/nope", "--n", "100",
         "--tile", "33"],
        cwd=PYDIR,
        capture_output=True,
    )
    assert r.returncode != 0
