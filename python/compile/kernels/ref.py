"""Pure-jnp oracles for the Pallas block kernels.

These define the semantics the kernels must match bit-for-bit (modulo
float accumulation order). The L2 model functions and the pytest suite
both compare against these.

Semantics (matching the rust engine's delta-accumulative model, in the
synchronous "process all scheduled blocks at once" form):

* ``pagerank_step``: consume the deltas of *masked* (scheduled)
  vertices; fold them into the values; propagate ``d * delta / outdeg``
  along out-edges. ``adj_norm[u, v] = d / outdeg(u)`` for each edge
  ``u -> v`` (zero elsewhere), so propagation is one matmul.

* ``sssp_step``: relax all out-edges of masked vertices:
  ``cand[j, v] = min_u(dist[j, u] + w[u, v])`` over masked ``u``;
  ``new_dist = min(dist, cand)``. ``w`` holds BIG for non-edges.
"""

import jax.numpy as jnp

# A large-but-finite stand-in for +inf: masking with true inf creates
# inf - inf NaN hazards under reordering; the rust side uses the same
# constant when building literals. Python float (not a jnp scalar) so
# Pallas kernels can close over it as a literal.
BIG = 3.0e38


def pagerank_step_ref(values, deltas, adj_norm, mask):
    """One masked synchronous delta-PageRank step.

    Args:
      values:   [J, N] accumulated PageRank values.
      deltas:   [J, N] pending deltas.
      adj_norm: [N, N] ``d/outdeg(u)`` at ``[u, v]`` per edge u->v.
      mask:     [N] 1.0 where the vertex's block is scheduled.

    Returns:
      (new_values [J, N], new_deltas [J, N])
    """
    consumed = deltas * mask[None, :]
    new_values = values + consumed
    new_deltas = deltas * (1.0 - mask)[None, :] + consumed @ adj_norm
    return new_values, new_deltas


def sssp_step_ref(dist, weights, mask):
    """One masked synchronous SSSP relaxation step.

    Args:
      dist:    [J, N] current best distances (BIG = unreached).
      weights: [N, N] edge weight at ``[u, v]``, BIG for non-edges.
      mask:    [N] 1.0 where the vertex's block is scheduled.

    Returns:
      new_dist [J, N]
    """
    # unmasked sources must not relax: push them to BIG
    src = jnp.where(mask[None, :] > 0, dist, BIG)
    cand = jnp.min(src[:, :, None] + weights[None, :, :], axis=1)
    return jnp.minimum(dist, jnp.minimum(cand, BIG))
