"""L1 Pallas kernel: batched masked delta-PageRank propagation.

The paper's cache insight re-expressed for TPU (DESIGN.md
§Hardware-Adaptation): one graph block (an ``adj_norm`` tile) is copied
HBM -> VMEM **once** and reused by all J concurrent jobs' delta rows —
the Pallas analogue of CAJS keeping a block hot in LLC while every
unconverged job processes it. Propagation is a [J, N] x [N, N] matmul
tiled (TILE_K x TILE_N) for the MXU; J rides the sublane axis.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU numbers are estimated in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, a_ref, o_ref, *, n_k_tiles):
    """Accumulating tile matmul: o[c] = sum_k x[k] @ a[k, c].

    Grid is (col_tiles, k_tiles); the k axis accumulates into o_ref,
    which Pallas keeps resident in VMEM across the k loop ("revisiting"
    the same output block).
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )


def matmul_tiled(x, a, *, tile_n=256, tile_k=256, interpret=True):
    """[J, K] @ [K, N] via the Pallas tile kernel."""
    j, k_dim = x.shape
    k_dim2, n = a.shape
    assert k_dim == k_dim2, (x.shape, a.shape)
    assert k_dim % tile_k == 0 and n % tile_n == 0, (x.shape, a.shape, tile_k, tile_n)
    n_k_tiles = k_dim // tile_k
    grid = (n // tile_n, n_k_tiles)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k_tiles=n_k_tiles),
        grid=grid,
        in_specs=[
            # x: all J rows, k-th K tile; same block for every col tile
            pl.BlockSpec((j, tile_k), lambda c, k: (0, k)),
            # a: (k, c) tile — the graph block; loaded once per (c, k)
            pl.BlockSpec((tile_k, tile_n), lambda c, k: (k, c)),
        ],
        out_specs=pl.BlockSpec((j, tile_n), lambda c, k: (0, c)),
        out_shape=jax.ShapeDtypeStruct((j, n), jnp.float32),
        interpret=interpret,
    )(x, a)


def auto_tile(n, preferred=256):
    """Largest power-of-two tile <= preferred that divides n."""
    t = preferred
    while t > 1 and n % t != 0:
        t //= 2
    return max(t, 1)


def pagerank_step(values, deltas, adj_norm, mask, *, tile=None, interpret=True):
    """One masked synchronous delta-PageRank step (kernel-backed).

    Matches ``ref.pagerank_step_ref`` exactly in semantics; the matmul
    runs through the Pallas tile kernel.
    """
    if tile is None:
        tile = auto_tile(values.shape[1])
    consumed = deltas * mask[None, :]
    new_values = values + consumed
    propagated = matmul_tiled(
        consumed, adj_norm, tile_n=tile, tile_k=tile, interpret=interpret
    )
    new_deltas = deltas * (1.0 - mask)[None, :] + propagated
    return new_values, new_deltas
