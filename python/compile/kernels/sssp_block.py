"""L1 Pallas kernel: batched masked SSSP relaxation (min-plus product).

Tropical-semiring analogue of the PageRank kernel: the same
one-block-serves-all-jobs VMEM schedule, with (min, +) instead of
(+, x). There is no MXU for min-plus, so this targets the VPU with
(8, 128)-shaped vector ops; the block tile is still fetched once per
grid step and shared across the J job lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG


def _minplus_kernel(x_ref, a_ref, o_ref, *, n_k_tiles):
    """o[c] = min_k minplus(x[k], a[k, c]) with BIG as identity."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, BIG)

    x = x_ref[...]  # [J, TK]
    a = a_ref[...]  # [TK, TN]
    # broadcast min-plus: [J, TK, 1] + [1, TK, TN] -> min over TK
    cand = jnp.min(x[:, :, None] + a[None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.minimum(cand, BIG))


def minplus_tiled(x, a, *, tile_n=256, tile_k=256, interpret=True):
    """Tropical [J, K] (min,+) [K, N] via the Pallas tile kernel."""
    j, k_dim = x.shape
    k_dim2, n = a.shape
    assert k_dim == k_dim2, (x.shape, a.shape)
    assert k_dim % tile_k == 0 and n % tile_n == 0
    n_k_tiles = k_dim // tile_k
    grid = (n // tile_n, n_k_tiles)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, n_k_tiles=n_k_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((j, tile_k), lambda c, k: (0, k)),
            pl.BlockSpec((tile_k, tile_n), lambda c, k: (k, c)),
        ],
        out_specs=pl.BlockSpec((j, tile_n), lambda c, k: (0, c)),
        out_shape=jax.ShapeDtypeStruct((j, n), jnp.float32),
        interpret=interpret,
    )(x, a)


def sssp_step(dist, weights, mask, *, tile=None, interpret=True):
    """One masked synchronous SSSP relaxation step (kernel-backed).

    Matches ``ref.sssp_step_ref``.
    """
    if tile is None:
        from .pagerank_block import auto_tile

        tile = auto_tile(dist.shape[1])
    src = jnp.where(mask[None, :] > 0, dist, BIG)
    cand = minplus_tiled(src, weights, tile_n=tile, tile_k=tile, interpret=interpret)
    return jnp.minimum(dist, jnp.minimum(cand, BIG))
