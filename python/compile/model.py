"""L2 JAX model: the batched multi-job block-update step.

Composes the L1 Pallas kernels into the two entry points the rust
runtime executes per scheduling round:

* ``pagerank_step_model`` — masked synchronous delta-PageRank step for
  J concurrent jobs.
* ``sssp_step_model`` — masked synchronous SSSP relaxation step.

The mask is the output of the rust scheduler (MPDS global priority
queue expanded to vertex granularity); the kernels do the compute.
Python exists only at build time — ``aot.py`` lowers these functions to
HLO text once, and the rust PJRT runtime replays them.
"""

import jax.numpy as jnp

from .kernels.pagerank_block import pagerank_step
from .kernels.sssp_block import sssp_step
from .kernels import ref


def pagerank_step_model(values, deltas, adj_norm, mask):
    """(values, deltas, adj_norm, mask) -> (new_values, new_deltas)."""
    return pagerank_step(values, deltas, adj_norm, mask)


def sssp_step_model(dist, weights, mask):
    """(dist, weights, mask) -> new_dist."""
    return sssp_step(dist, weights, mask)


def pagerank_step_reference(values, deltas, adj_norm, mask):
    """Oracle-backed variant (no Pallas) — lowered alongside the kernel
    version so the rust integration tests can cross-check numerics of
    both artifact flavours."""
    return ref.pagerank_step_ref(values, deltas, adj_norm, mask)


def sssp_step_reference(dist, weights, mask):
    return ref.sssp_step_ref(dist, weights, mask)


def build_adj_norm(n, edges, out_degrees, damping=0.85):
    """Dense ``adj_norm`` from an edge list (test helper; the rust side
    builds the same matrix from its CSR)."""
    a = jnp.zeros((n, n), dtype=jnp.float32)
    for (u, v) in edges:
        a = a.at[u, v].add(damping / out_degrees[u])
    return a
