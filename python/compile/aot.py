"""AOT lowering: L2 model -> HLO text artifacts for the rust runtime.

HLO *text* is the interchange format (NOT ``lowered.compile()`` /
serialized protos): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla``
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts [--jobs 8] [--n 1024] [--tile 256]

Writes one ``.hlo.txt`` per entry point plus ``manifest.json``
describing shapes, so the rust loader never guesses.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    pagerank_step_model,
    pagerank_step_reference,
    sssp_step_model,
    sssp_step_reference,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--jobs", type=int, default=8, help="J: concurrent job lanes")
    ap.add_argument("--n", type=int, default=1024, help="N: padded vertex count")
    ap.add_argument("--tile", type=int, default=256, help="kernel tile size")
    args = ap.parse_args()

    j, n = args.jobs, args.n
    assert n % args.tile == 0, "n must be a multiple of tile"

    f32 = jnp.float32
    lane = jax.ShapeDtypeStruct((j, n), f32)
    mat = jax.ShapeDtypeStruct((n, n), f32)
    mask = jax.ShapeDtypeStruct((n,), f32)

    entries = [
        ("pagerank_step", pagerank_step_model, (lane, lane, mat, mask)),
        ("pagerank_step_ref", pagerank_step_reference, (lane, lane, mat, mask)),
        ("sssp_step", sssp_step_model, (lane, mat, mask)),
        ("sssp_step_ref", sssp_step_reference, (lane, mat, mask)),
    ]

    os.makedirs(args.out, exist_ok=True)
    manifest = {"jobs": j, "n": n, "tile": args.tile, "entries": []}
    for name, fn, ex in entries:
        lowered = lower_entry(fn, ex)
        text = to_hlo_text(lowered)
        fname = f"{name}_j{j}_n{n}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        n_inputs = len(ex)
        n_outputs = len(lowered.out_info) if isinstance(lowered.out_info, tuple) else 1
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": n_inputs,
                "outputs": n_outputs,
                "hlo_bytes": len(text),
            }
        )
        print(f"wrote {path} ({len(text)} bytes)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
