#!/usr/bin/env bash
# Refresh the committed bench-gate baseline from a measured candidate.
#
# Usage:
#   scripts/refresh_bench_baseline.sh <BENCH_baseline_candidate.json>
#
# The candidate comes from the `bench-fused` artifact of a *green*
# bench-smoke CI run (or a local `cargo bench --bench throughput --
# ... --write-baseline BENCH_baseline_candidate.json` on a quiet
# machine). Candidates always carry `updates_verified: 1` — they were
# measured by the run that wrote them — so copying one (re)arms the
# hard-failing exact work-to-convergence check in the gate.
#
# Never hand-edit speedup values into BENCH_baseline.json: unmeasured
# floors either mask regressions (too low) or flake CI (too high).
set -euo pipefail

cd "$(dirname "$0")/.."

candidate="${1:?usage: $0 <BENCH_baseline_candidate.json>}"
[ -f "$candidate" ] || { echo "error: $candidate not found" >&2; exit 1; }

python3 - "$candidate" <<'EOF'
import json, sys

cand = json.load(open(sys.argv[1]))
required = [
    "scale", "jobs", "updates", "updates_verified",
    "speedup_fused_seq", "speedup_fused_parallel",
    "speedup_dispatch_persistent", "speedup_shards_2", "speedup_shards_4",
]
missing = [k for k in required if k not in cand]
assert not missing, f"candidate missing keys: {missing}"
assert cand["updates_verified"], "candidate is not a measured baseline"
assert cand["updates"] > 0, "candidate recorded zero work-to-convergence"

old = json.load(open("BENCH_baseline.json"))
for k in required:
    if k in old and isinstance(old[k], (int, float)):
        print(f"  {k}: {old[k]} -> {cand[k]}")
cand["bench"] = old.get("bench", "fused_vs_perjob")
cand["note"] = old.get("note", "")

with open("BENCH_baseline.json", "w") as f:
    json.dump(cand, f)
    f.write("\n")
print("BENCH_baseline.json refreshed; review the diff and commit it.")
EOF
