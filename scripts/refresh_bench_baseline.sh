#!/usr/bin/env bash
# Refresh the committed bench-gate baseline from a measured candidate.
#
# Usage:
#   scripts/refresh_bench_baseline.sh <BENCH_baseline_candidate.json> \
#       [BENCH_serve.json] [BENCH_locality.json]
#
# The candidate comes from the `bench-fused` artifact of a *green*
# bench-smoke CI run (or a local `cargo bench --bench throughput --
# ... --write-baseline BENCH_baseline_candidate.json` on a quiet
# machine). Candidates always carry `updates_verified: 1` — they were
# measured by the run that wrote them — so copying one (re)arms the
# hard-failing exact work-to-convergence check in the gate.
#
# The optional second argument is the `bench-serve` artifact of a
# green net-e2e run (the loadgen smoke's latency report). Passing it
# folds the serving keys — serve_p50_latency_s, serve_p95_latency_s,
# serve_completed_per_s — into the baseline and sets
# `serve_verified: 1`, which arms the hard-failing serve-latency gate
# in the net-e2e job. Without it, the previous serve_* values are
# preserved unchanged.
#
# The optional third argument is the `BENCH_locality.json` from the
# metrics-e2e profile step (or a local `tlsched profile` run). Passing
# it folds the locality_* keys — per-mode miss rates, stall shares,
# DRAM bytes, and locality_traffic_ratio — into the baseline with
# `locality_verified` carried over from the report. Without it, any
# previous locality_* values are preserved unchanged.
#
# Never hand-edit speedup or latency values into BENCH_baseline.json:
# unmeasured floors either mask regressions (too low) or flake CI
# (too high).
set -euo pipefail

cd "$(dirname "$0")/.."

candidate="${1:?usage: $0 <BENCH_baseline_candidate.json> [BENCH_serve.json] [BENCH_locality.json]}"
[ -f "$candidate" ] || { echo "error: $candidate not found" >&2; exit 1; }
serve="${2:-}"
if [ -n "$serve" ] && [ ! -f "$serve" ]; then
    echo "error: $serve not found" >&2
    exit 1
fi
locality="${3:-}"
if [ -n "$locality" ] && [ ! -f "$locality" ]; then
    echo "error: $locality not found" >&2
    exit 1
fi

python3 - "$candidate" "$serve" "$locality" <<'EOF'
import json, sys

cand = json.load(open(sys.argv[1]))
required = [
    "scale", "jobs", "updates", "updates_verified",
    "speedup_fused_seq", "speedup_fused_parallel",
    "speedup_dispatch_persistent", "speedup_shards_2", "speedup_shards_4",
]
missing = [k for k in required if k not in cand]
assert not missing, f"candidate missing keys: {missing}"
assert cand["updates_verified"], "candidate is not a measured baseline"
assert cand["updates"] > 0, "candidate recorded zero work-to-convergence"

serve_keys = ["serve_p50_latency_s", "serve_p95_latency_s", "serve_completed_per_s"]
old = json.load(open("BENCH_baseline.json"))
for k in required:
    if k in old and isinstance(old[k], (int, float)):
        print(f"  {k}: {old[k]} -> {cand[k]}")
cand["bench"] = old.get("bench", "fused_vs_perjob")
cand["note"] = old.get("note", "")

if sys.argv[2]:
    smoke = json.load(open(sys.argv[2]))
    smoke_required = ["p50_latency_s", "p95_latency_s", "completed_per_s", "done"]
    missing = [k for k in smoke_required if k not in smoke]
    assert not missing, f"serve report missing keys: {missing}"
    assert smoke["done"] > 0, "serve report recorded zero completions"
    assert smoke["p95_latency_s"] > 0, "serve report recorded zero p95 latency"
    cand["serve_p50_latency_s"] = smoke["p50_latency_s"]
    cand["serve_p95_latency_s"] = smoke["p95_latency_s"]
    cand["serve_completed_per_s"] = smoke["completed_per_s"]
    cand["serve_verified"] = 1
    for k in serve_keys:
        print(f"  {k}: {old.get(k, 0.0)} -> {cand[k]}")
    print("  serve_verified: "
          f"{old.get('serve_verified', 0)} -> 1 (serve latency gate armed)")
else:
    # preserve the serving baseline unchanged
    for k in serve_keys:
        cand[k] = old.get(k, 0.0)
    cand["serve_verified"] = old.get("serve_verified", 0)

if sys.argv[3]:
    prof = json.load(open(sys.argv[3]))
    loc_required = ["locality_traffic_ratio", "locality_verified",
                    "locality_fused_dram_bytes", "locality_perjob_dram_bytes"]
    missing = [k for k in loc_required if k not in prof]
    assert not missing, f"locality report missing keys: {missing}"
    assert prof["locality_verified"], \
        "locality report is unverified (fused did not beat per-job)"
    for k, v in sorted(prof.items()):
        if k.startswith("locality_"):
            cand[k] = v
    print(f"  locality_traffic_ratio: {old.get('locality_traffic_ratio', 'unset')}"
          f" -> {prof['locality_traffic_ratio']}")
else:
    # preserve any previous locality profile unchanged
    for k, v in old.items():
        if k.startswith("locality_"):
            cand[k] = v

with open("BENCH_baseline.json", "w") as f:
    json.dump(cand, f)
    f.write("\n")
print("BENCH_baseline.json refreshed; review the diff and commit it.")
EOF
